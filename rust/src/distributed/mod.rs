//! Distributed (diffusion) kernel adaptive filtering over a simulated
//! network — the extension the paper's §7 / ref [21] points to, and the
//! setting its intro uses to motivate fixed-size solutions: cooperating
//! nodes exchange `θ ∈ R^D` vectors instead of dictionaries, so no
//! dictionary matching and constant per-link payload. The combine/adapt
//! scheme follows the RKHS-diffusion follow-up (Bouboulis et al., 2017,
//! arXiv:1703.08131), which builds exactly on this fixed-size property.
//!
//! One diffusion round, over Metropolis weights `A` on an arbitrary
//! undirected graph (both orderings supported):
//!
//! ```text
//! CTA:  φ_k = Σ_l a_lk θ_l                 (combine)
//!       θ_k = φ_k + gain_k · z(x_k)        (adapt; e_k = y_k − φ_kᵀ z(x_k))
//! ATC:  ψ_k = θ_k + gain_k · z(x_k)        (adapt; e_k = y_k − θ_kᵀ z(x_k))
//!       θ_k = Σ_l a_lk ψ_l                 (combine)
//! ```
//!
//! with `gain = μ e` (diffusion RFF-KLMS) or `μ e / (ε + ‖z‖²)`
//! (diffusion RFF-NLMS).
//!
//! Built on the crate's current substrate (ISSUE 5): the combine is the
//! lane-oriented multi-axpy
//! ([`weighted_combine_rows`](crate::linalg::simd::weighted_combine_rows)),
//! features run the blocked batch kernels over whole windows of rounds
//! ([`DiffusionNetwork::step_batch_into`] — bitwise identical to
//! per-round stepping), the whole group shares **one** interned
//! `Arc<RffMap>`, and groups are served, snapshot and spilled through
//! the coordinator as first-class sessions
//! (`coordinator::Request::TrainDiffusion`,
//! [`coordinator::DiffusionGroupConfig`](crate::coordinator::DiffusionGroupConfig)).
//! [`codec`] is the standalone checkpoint document; [`TrafficReport`]
//! prices the fixed-payload advantage against dictionary diffusion.

pub mod codec;
mod network;
mod traffic;

pub use codec::{load_diffusion, save_diffusion, save_diffusion_with, DiffusionState};
pub use network::{DiffusionAlgo, DiffusionNetwork, DiffusionOrdering, NetworkTopology};
pub use traffic::{
    dict_matching_ops, dict_payload_bytes, dict_traffic_bytes, rff_payload_bytes,
    rff_traffic_bytes, TrafficReport,
};
