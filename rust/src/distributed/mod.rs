//! Distributed (diffusion) RFF-KLMS over a simulated network — the
//! extension the paper's §7 / ref [21] points to, and the setting its
//! intro uses to motivate fixed-size solutions: cooperating nodes
//! exchange `θ ∈ R^D` vectors instead of dictionaries, so no dictionary
//! matching and constant per-link payload.
//!
//! Combine-then-adapt (CTA) diffusion:
//! ```text
//! φ_k = Σ_l a_{lk} θ_l         (combine over neighbors, A doubly sym.)
//! θ_k = φ_k + μ e_k z(x_k),    e_k = y_k − φ_kᵀ z(x_k)
//! ```
//! with Metropolis combination weights on an arbitrary undirected graph.

mod network;
mod traffic;

pub use network::{DiffusionRffKlms, NetworkTopology};
pub use traffic::{
    dict_matching_ops, dict_payload_bytes, dict_traffic_bytes, rff_payload_bytes,
    rff_traffic_bytes, TrafficReport,
};
