//! Checkpoint codec for diffusion networks — the distributed extension
//! of [`kaf::checkpoint`](crate::kaf::checkpoint): the same versioned
//! document format (`"format"` = [`CHECKPOINT_FORMAT`], map inline or by
//! [`MapSpec`](crate::kaf::MapSpec) registry reference), carrying the
//! whole group — topology, ordering, adapt rule and every node's θ.
//!
//! Documents are **shape-validated with diagnostics**: a node-count /
//! topology / θ-length mismatch is a descriptive `Err`, never a panic or
//! a misparse. The state-body codec ([`DiffusionState`]) is shared with
//! the coordinator's session snapshots (`coordinator::SessionSnapshot`),
//! so a group serialized by the service's spill path and one serialized
//! here agree on the layout.
//!
//! Round-trip exactness: θ arrays are f64 and round-trip bitwise; the
//! topology round-trips through its canonical edge list
//! ([`NetworkTopology::edges`]), whose reconstruction yields identical
//! adjacency order and therefore bitwise-identical combines — restoring
//! a group and continuing to train equals the uninterrupted run exactly
//! (property-tested in `tests/diffusion_parity.rs`).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::kaf::checkpoint::{
    arr, check_format, get_arr, get_num, get_str, get_usize, MapPayload, CHECKPOINT_FORMAT,
};
use crate::kaf::{MapRegistry, RffMap};
use crate::util::json::JsonValue;

use super::network::{DiffusionAlgo, DiffusionNetwork, DiffusionOrdering, NetworkTopology};

/// The decoded group state body, before a map/network is constructed —
/// shared by this codec and the coordinator's session-snapshot codec.
pub struct DiffusionState {
    /// Node count.
    pub nodes: usize,
    /// Canonical undirected edge list.
    pub edges: Vec<(usize, usize)>,
    /// Half-step ordering.
    pub ordering: DiffusionOrdering,
    /// Row-major `[nodes, D]` per-node weights.
    pub thetas: Vec<f64>,
}

impl DiffusionState {
    /// Capture a live network's state body.
    pub fn of(net: &DiffusionNetwork) -> Self {
        Self {
            nodes: net.nodes(),
            edges: net.topology().edges(),
            ordering: net.ordering(),
            thetas: net.thetas().to_vec(),
        }
    }

    /// Shape-check the body against a feature count: node count and the
    /// `[nodes, D]` θ payload must agree. The single source of the
    /// "node count and topology disagree" diagnostic — called both by
    /// the session-snapshot parser (up-front, so a corrupt document
    /// errors at parse) and by [`Self::build_topology`] at restore.
    pub fn validate(&self, features: usize) -> Result<()> {
        anyhow::ensure!(self.nodes > 0, "diffusion group document has zero nodes");
        anyhow::ensure!(
            self.thetas.len() == self.nodes * features,
            "per-node θ payload has {} numbers but {} nodes × {} features \
             need {} — node count and topology disagree with the state",
            self.thetas.len(),
            self.nodes,
            features,
            self.nodes * features
        );
        Ok(())
    }

    /// Validate the body against a feature count and build the topology,
    /// with diagnostic errors for every mismatch a document can carry.
    pub fn build_topology(&self, features: usize) -> Result<NetworkTopology> {
        self.validate(features)?;
        NetworkTopology::try_new(self.nodes, &self.edges)
            .context("diffusion group document carries an invalid topology")
    }

    /// Serialize the body into a JSON object's fields.
    pub fn write_fields(&self, obj: &mut BTreeMap<String, JsonValue>) {
        obj.insert("ordering".into(), JsonValue::String(self.ordering.name().into()));
        obj.insert("nodes".into(), JsonValue::Number(self.nodes as f64));
        obj.insert(
            "edges".into(),
            arr(self.edges.iter().flat_map(|&(a, b)| [a as f64, b as f64])),
        );
        obj.insert("thetas".into(), arr(self.thetas.iter().copied()));
    }

    /// Parse the body out of a JSON object (shape-checked; topology
    /// validity is checked by [`Self::build_topology`]).
    pub fn parse_fields(v: &JsonValue) -> Result<Self> {
        let ordering = DiffusionOrdering::from_name(get_str(v, "ordering")?)?;
        let nodes = get_usize(v, "nodes")?;
        let flat = get_arr(v, "edges")?;
        anyhow::ensure!(
            flat.len() % 2 == 0,
            "diffusion edges array has odd length {} (must be (a, b) pairs)",
            flat.len()
        );
        let edges = flat
            .chunks_exact(2)
            .map(|p| {
                let (a, b) = (p[0], p[1]);
                anyhow::ensure!(
                    a.fract() == 0.0 && b.fract() == 0.0 && a >= 0.0 && b >= 0.0,
                    "diffusion edge ({a}, {b}) is not a pair of node indices"
                );
                Ok((a as usize, b as usize))
            })
            .collect::<Result<Vec<_>>>()?;
        let thetas = get_arr(v, "thetas")?;
        Ok(Self { nodes, edges, ordering, thetas })
    }
}

fn adapt_to_json(algo: DiffusionAlgo) -> JsonValue {
    let mut obj = BTreeMap::new();
    match algo {
        DiffusionAlgo::Klms { mu } => {
            obj.insert("type".into(), JsonValue::String("klms".into()));
            obj.insert("mu".into(), JsonValue::Number(mu));
        }
        DiffusionAlgo::Nlms { mu, eps } => {
            obj.insert("type".into(), JsonValue::String("nlms".into()));
            obj.insert("mu".into(), JsonValue::Number(mu));
            obj.insert("eps".into(), JsonValue::Number(eps));
        }
    }
    JsonValue::Object(obj)
}

/// Ranges are checked at this parse boundary: `DiffusionNetwork::new`
/// `assert!`s the same bounds, and a corrupt document must be a
/// diagnostic error, never a panic inside a restore.
fn adapt_from_json(v: &JsonValue) -> Result<DiffusionAlgo> {
    let mu = get_num(v, "mu")?;
    anyhow::ensure!(mu > 0.0 && mu.is_finite(), "adapt mu must be positive");
    match get_str(v, "type")? {
        "klms" => Ok(DiffusionAlgo::Klms { mu }),
        "nlms" => {
            let eps = get_num(v, "eps")?;
            anyhow::ensure!(eps >= 0.0 && eps.is_finite(), "adapt eps must be non-negative");
            Ok(DiffusionAlgo::Nlms { mu, eps })
        }
        other => anyhow::bail!("unknown diffusion adapt rule '{other}'"),
    }
}

/// Serialize a diffusion network (map inline).
pub fn save_diffusion(net: &DiffusionNetwork) -> String {
    save_diffusion_with(net, MapPayload::Inline(Arc::clone(net.map_arc())))
}

/// Serialize a diffusion network with an explicit map payload (pass a
/// [`MapPayload::Reference`] to store the shared map by spec — a group
/// document then costs O(n·D) for the θ rows, not O(n·D + d·D) more for
/// the map every group in a fleet shares anyway).
pub fn save_diffusion_with(net: &DiffusionNetwork, map: MapPayload) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("format".into(), JsonValue::Number(CHECKPOINT_FORMAT as f64));
    obj.insert("algo".into(), JsonValue::String("diffusion".into()));
    obj.insert("map".into(), map.to_json());
    obj.insert("adapt".into(), adapt_to_json(net.algo()));
    DiffusionState::of(net).write_fields(&mut obj);
    JsonValue::Object(obj).to_string_pretty()
}

/// Restore a diffusion network from [`save_diffusion`] output.
/// Reference-mode maps resolve through `registry` so restored groups
/// keep sharing the fleet's interned `(Ω, b)`. Every shape mismatch a
/// document can carry — θ length vs nodes × features, out-of-range or
/// self-loop edges, odd edge arrays — is a diagnostic error.
pub fn load_diffusion(text: &str, registry: Option<&MapRegistry>) -> Result<DiffusionNetwork> {
    let v = JsonValue::parse(text).context("parsing diffusion checkpoint")?;
    check_format(&v)?;
    let found = get_str(&v, "algo")?;
    anyhow::ensure!(found == "diffusion", "not a diffusion checkpoint (found '{found}')");
    let map = MapPayload::from_json(v.get("map").ok_or_else(|| anyhow!("missing map"))?)?;
    let adapt = adapt_from_json(v.get("adapt").ok_or_else(|| anyhow!("missing adapt"))?)?;
    let state = DiffusionState::parse_fields(&v)?;
    let map: Arc<RffMap> = map.resolve(registry);
    anyhow::ensure!(
        !map.kind().is_adaptive(),
        "diffusion documents require a frozen map kind (got '{}'): every node \
         shares one (Ω, b) and exchanges θ only",
        map.kind().name()
    );
    let topo = state.build_topology(map.features())?;
    let mut net = DiffusionNetwork::new(topo, map, adapt, state.ordering);
    net.restore_thetas(state.thetas);
    Ok(net)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kaf::kernels::Kernel;
    use crate::kaf::MapSpec;
    use crate::rng::run_rng;
    use crate::signal::{NonlinearWiener, SignalSource};

    fn trained_net(feats: usize) -> DiffusionNetwork {
        let mut rng = run_rng(1, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, feats);
        let mut net = DiffusionNetwork::new(
            NetworkTopology::ring(4),
            map,
            DiffusionAlgo::Klms { mu: 0.5 },
            DiffusionOrdering::AdaptThenCombine,
        );
        let mut src = NonlinearWiener::new(run_rng(1, 1), 0.05);
        for s in src.take_samples(60) {
            let mut xs = Vec::new();
            for _ in 0..4 {
                xs.extend_from_slice(&s.x);
            }
            net.step(&xs, &vec![s.y; 4]);
        }
        net
    }

    #[test]
    fn diffusion_roundtrip_continues_bitwise() {
        let mut original = trained_net(24);
        let text = save_diffusion(&original);
        assert!(text.contains("\"algo\": \"diffusion\""));
        let mut restored = load_diffusion(&text, None).unwrap();
        assert_eq!(restored.thetas(), original.thetas());
        assert_eq!(restored.ordering(), original.ordering());
        assert_eq!(restored.topology().edges(), original.topology().edges());
        // identical continuation — topology reconstruction kept the
        // canonical combine order
        let mut src = NonlinearWiener::new(run_rng(2, 0), 0.05);
        for s in src.take_samples(40) {
            let mut xs = Vec::new();
            for _ in 0..4 {
                xs.extend_from_slice(&s.x);
            }
            let a = original.step(&xs, &vec![s.y; 4]);
            let b = restored.step(&xs, &vec![s.y; 4]);
            assert_eq!(a, b, "trajectories diverged after restore");
        }
        assert_eq!(restored.thetas(), original.thetas());
    }

    #[test]
    fn reference_map_group_restores_shared_through_registry() {
        let registry = MapRegistry::new();
        let spec = MapSpec::new(Kernel::Gaussian { sigma: 5.0 }, 5, 32, 77);
        let map = registry.get_or_draw(&spec);
        let net = DiffusionNetwork::new(
            NetworkTopology::complete(3),
            Arc::clone(&map),
            DiffusionAlgo::Nlms { mu: 0.5, eps: 1e-6 },
            DiffusionOrdering::CombineThenAdapt,
        );
        let text = save_diffusion_with(&net, MapPayload::Reference(spec));
        assert!(text.len() < save_diffusion(&net).len() / 2, "reference doc should be small");
        let restored = load_diffusion(&text, Some(&registry)).unwrap();
        assert!(Arc::ptr_eq(restored.map_arc(), &map), "restored group must share the map");
        assert_eq!(restored.algo(), net.algo());
    }

    /// Parse `text`, mutate the top-level object, re-serialize — the
    /// hand-built-bad-document helper (string replacement is too brittle
    /// against the pretty-printer's array layout).
    fn mutate(text: &str, f: impl FnOnce(&mut BTreeMap<String, JsonValue>)) -> String {
        let mut v = JsonValue::parse(text).unwrap();
        let JsonValue::Object(obj) = &mut v else { unreachable!("checkpoint is an object") };
        f(obj);
        v.to_string_compact()
    }

    #[test]
    fn mismatched_group_documents_are_diagnostic_errors() {
        // satellite: node-count/topology mismatches must be descriptive
        // errors, never a misparse or a panic inside a constructor
        let text = save_diffusion(&trained_net(16));

        // θ payload for 4 nodes relabelled as 3 nodes: length mismatch
        let bad_nodes =
            mutate(&text, |o| drop(o.insert("nodes".into(), JsonValue::Number(3.0))));
        let err = load_diffusion(&bad_nodes, None).unwrap_err().to_string();
        assert!(
            err.contains("node count and topology disagree"),
            "unhelpful error: {err}"
        );

        // an edge pointing past the node count
        let bad_edge = mutate(&text, |o| drop(o.insert("edges".into(), arr([0.0, 9.0]))));
        let err = format!("{:#}", load_diffusion(&bad_edge, None).unwrap_err());
        assert!(err.contains("out of range"), "unhelpful error: {err}");

        // a self loop
        let self_loop = mutate(&text, |o| drop(o.insert("edges".into(), arr([1.0, 1.0]))));
        let err = format!("{:#}", load_diffusion(&self_loop, None).unwrap_err());
        assert!(err.contains("self loop"), "unhelpful error: {err}");

        // an odd-length edge array cannot be (a, b) pairs
        let odd = mutate(&text, |o| drop(o.insert("edges".into(), arr([0.0, 1.0, 2.0]))));
        let err = load_diffusion(&odd, None).unwrap_err().to_string();
        assert!(err.contains("odd length"), "unhelpful error: {err}");

        // wrong algo tag and unknown ordering are rejected
        let wrong_algo = mutate(&text, |o| {
            drop(o.insert("algo".into(), JsonValue::String("rffklms".into())))
        });
        assert!(load_diffusion(&wrong_algo, None).is_err());
        let bad_ordering = mutate(&text, |o| {
            drop(o.insert("ordering".into(), JsonValue::String("sideways".into())))
        });
        assert!(load_diffusion(&bad_ordering, None).is_err());

        // out-of-range adapt hyperparameters are diagnostic errors at
        // parse, not a panic inside DiffusionNetwork::new during restore
        let bad_mu = mutate(&text, |o| {
            let mut adapt = BTreeMap::new();
            adapt.insert("type".into(), JsonValue::String("klms".into()));
            adapt.insert("mu".into(), JsonValue::Number(-1.0));
            drop(o.insert("adapt".into(), JsonValue::Object(adapt)));
        });
        let err = load_diffusion(&bad_mu, None).unwrap_err().to_string();
        assert!(err.contains("mu must be positive"), "unhelpful error: {err}");
    }

    #[test]
    fn quadrature_group_roundtrips() {
        // any *static* map kind backs a group — the deterministic grid
        // travels inline (weights + order) and by reference
        let kernel = Kernel::Gaussian { sigma: 1.0 };
        let map = RffMap::quadrature(kernel, 2, 3).unwrap();
        let mut net = DiffusionNetwork::new(
            NetworkTopology::ring(3),
            map,
            DiffusionAlgo::Klms { mu: 0.3 },
            DiffusionOrdering::CombineThenAdapt,
        );
        for i in 0..20 {
            let t = i as f64 * 0.29;
            let xs = [t.sin(), t.cos(), (t * 1.1).sin(), (t * 1.1).cos(), 0.5, -0.5];
            net.step(&xs, &[(t * 0.8).sin(); 3]);
        }
        let text = save_diffusion(&net);
        assert!(text.contains("\"kind\": \"quadrature\""));
        let mut restored = load_diffusion(&text, None).unwrap();
        assert_eq!(restored.thetas(), net.thetas());
        for i in 0..10 {
            let t = i as f64 * 0.41;
            let xs = [t.cos(), t.sin(), (t * 0.7).cos(), (t * 0.7).sin(), 0.1, 0.2];
            assert_eq!(
                net.step(&xs, &[t.cos(); 3]),
                restored.step(&xs, &[t.cos(); 3]),
                "quadrature group trajectories diverged after restore"
            );
        }
        // by reference: the spec re-derives the identical grid
        let spec = MapSpec::quadrature(kernel, 2, 3).unwrap();
        let by_ref = save_diffusion_with(&net, MapPayload::Reference(spec));
        let again = load_diffusion(&by_ref, None).unwrap();
        assert_eq!(again.thetas(), net.thetas());
        assert_eq!(again.map().weights().unwrap(), net.map().weights().unwrap());
    }

    #[test]
    fn adaptive_map_in_group_document_is_diagnostic() {
        // an adaptive inline map smuggled into a diffusion document must
        // be a descriptive error, not a panic in DiffusionNetwork::new
        let text = save_diffusion(&trained_net(16));
        let doc = mutate(&text, |o| {
            let Some(JsonValue::Object(map)) = o.get_mut("map") else {
                unreachable!("document has a map object")
            };
            map.insert("kind".into(), JsonValue::String("adaptive_rff".into()));
            map.insert("mu_omega".into(), JsonValue::Number(0.01));
        });
        let err = load_diffusion(&doc, None).unwrap_err().to_string();
        assert!(err.contains("frozen map kind"), "unhelpful error: {err}");
    }

    #[test]
    fn hand_built_minimal_document_loads() {
        // a document written by another tool, smallest valid shape:
        // 2 nodes, one edge, inline 1-feature map
        let doc = r#"{
            "format": 3,
            "algo": "diffusion",
            "map": {"mode": "inline", "dim": 1, "omega": [0.5], "phases": [0.25]},
            "adapt": {"type": "klms", "mu": 1.0},
            "ordering": "cta",
            "nodes": 2,
            "edges": [0, 1],
            "thetas": [0.125, -0.5]
        }"#;
        let net = load_diffusion(doc, None).unwrap();
        assert_eq!(net.nodes(), 2);
        assert_eq!(net.theta(0), &[0.125]);
        assert_eq!(net.theta(1), &[-0.5]);
        assert_eq!(net.topology().edges(), vec![(0, 1)]);
    }
}
