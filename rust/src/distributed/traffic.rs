//! Network-traffic accounting for distributed kernel adaptive filtering —
//! the paper's intro argument made quantitative: diffusion with
//! dictionary-based filters ships *growing dictionaries* and must match
//! them across neighbors, while RFF filters ship a fixed `D`-float θ.
//!
//! The models here follow the diffusion-KLMS literature (refs [14–16]):
//! per combine round each node sends its model to each neighbor.

/// Bytes to serialize one f64.
const F64_BYTES: usize = 8;

/// Per-link payload (bytes) of one RFF-diffusion combine round.
pub fn rff_payload_bytes(features: usize) -> usize {
    features * F64_BYTES
}

/// Per-link payload (bytes) of one dictionary-diffusion combine round
/// with a dictionary of `m` centers in `d` dimensions: centers + one
/// coefficient each.
pub fn dict_payload_bytes(m: usize, d: usize) -> usize {
    m * (d + 1) * F64_BYTES
}

/// Cumulative traffic (bytes) over a run for a network with `links`
/// directed links, given the dictionary-size trajectory `m_per_step`
/// (dictionary filters) — each step every link carries the current model.
pub fn dict_traffic_bytes(links: usize, d: usize, m_per_step: &[usize]) -> u64 {
    m_per_step
        .iter()
        .map(|&m| (links * dict_payload_bytes(m, d)) as u64)
        .sum()
}

/// Cumulative RFF traffic over `steps` rounds.
pub fn rff_traffic_bytes(links: usize, features: usize, steps: usize) -> u64 {
    (links * rff_payload_bytes(features)) as u64 * steps as u64
}

/// Dictionary-matching work: merging a neighbor dictionary of `m_other`
/// centers into ours of `m_self` requires a nearest-center search per
/// received center — `O(m_self · m_other · d)` multiply-adds. Returns
/// the per-round op count for one link.
pub fn dict_matching_ops(m_self: usize, m_other: usize, d: usize) -> u64 {
    (m_self as u64) * (m_other as u64) * (d as u64)
}

/// Traffic comparison report for a QKLMS-vs-RFF diffusion run.
#[derive(Clone, Debug)]
pub struct TrafficReport {
    /// Directed links in the topology.
    pub links: usize,
    /// Combine rounds.
    pub steps: usize,
    /// Total RFF bytes.
    pub rff_bytes: u64,
    /// Total dictionary bytes.
    pub dict_bytes: u64,
    /// Total dictionary-matching multiply-adds (RFF needs none).
    pub dict_matching: u64,
}

impl TrafficReport {
    /// Build from a dictionary-size trajectory.
    pub fn compare(
        links: usize,
        d: usize,
        features: usize,
        m_per_step: &[usize],
    ) -> TrafficReport {
        let steps = m_per_step.len();
        let dict_bytes = dict_traffic_bytes(links, d, m_per_step);
        let matching: u64 = m_per_step
            .iter()
            .map(|&m| dict_matching_ops(m, m, d) * links as u64)
            .sum();
        TrafficReport {
            links,
            steps,
            rff_bytes: rff_traffic_bytes(links, features, steps),
            dict_bytes,
            dict_matching: matching,
        }
    }

    /// dictionary/RFF traffic ratio.
    pub fn bytes_ratio(&self) -> f64 {
        self.dict_bytes as f64 / self.rff_bytes.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kaf::kernels::Kernel;
    use crate::kaf::{OnlineRegressor, Qklms};
    use crate::rng::run_rng;
    use crate::signal::{NonlinearWiener, SignalSource};

    #[test]
    fn payload_formulas() {
        assert_eq!(rff_payload_bytes(300), 2400);
        assert_eq!(dict_payload_bytes(100, 5), 4800);
        assert_eq!(dict_matching_ops(100, 100, 5), 50_000);
    }

    #[test]
    fn rff_traffic_is_constant_per_round() {
        let a = rff_traffic_bytes(10, 300, 1);
        let b = rff_traffic_bytes(10, 300, 1000);
        assert_eq!(b, 1000 * a);
    }

    #[test]
    fn qklms_diffusion_traffic_overtakes_rff() {
        // Measure a real QKLMS dictionary trajectory on Ex. 2 and show
        // the cumulative traffic crossing over the fixed RFF payload —
        // the intro's distributed-learning argument, quantified.
        let mut q = Qklms::new(Kernel::Gaussian { sigma: 5.0 }, 5, 1.0, 5.0);
        let mut src = NonlinearWiener::new(run_rng(1, 0), 0.05);
        let mut m_traj = Vec::new();
        for s in src.take_samples(12000) {
            q.step(&s.x, s.y);
            m_traj.push(q.dictionary_size());
        }
        let report = TrafficReport::compare(16, 5, 300, &m_traj);
        // QKLMS reaches M ~ 100 on d=5: at steady state the per-round
        // dict payload 100*(5+1)*8 = 4800 B doubles RFF's 2400 B (the
        // cumulative ratio is lower because M ramps from 0).
        let steady_ratio = dict_payload_bytes(*m_traj.last().unwrap(), 5) as f64
            / rff_payload_bytes(300) as f64;
        assert!(steady_ratio > 1.5, "steady payload ratio {steady_ratio}");
        // cumulative ratio grows with horizon (ramp washes out)
        let head = TrafficReport::compare(16, 5, 300, &m_traj[..1000.min(m_traj.len())]);
        assert!(report.bytes_ratio() > head.bytes_ratio());
        // and RFF needs zero matching ops while QKLMS pays O(M^2 d)/round
        assert!(report.dict_matching > 0);
    }

    #[test]
    fn higher_dimensions_widen_the_gap() {
        // d=10, tighter epsilon: dictionaries explode, traffic ratio grows
        let mut q = Qklms::new(Kernel::Gaussian { sigma: 5.0 }, 10, 1.0, 1.0);
        let mut src = NonlinearWiener::with_dim(run_rng(2, 0), 10, 0.05);
        let mut m_traj = Vec::new();
        for s in src.take_samples(3000) {
            q.step(&s.x, s.y);
            m_traj.push(q.dictionary_size());
        }
        let report = TrafficReport::compare(16, 10, 300, &m_traj);
        assert!(
            report.bytes_ratio() > 5.0,
            "expected large-M regime, ratio {}",
            report.bytes_ratio()
        );
    }
}
