//! Network topology + diffusion RFF-KLMS.

use crate::kaf::RffMap;
use crate::linalg::{axpy, dot};

/// Undirected network topology with Metropolis combination weights.
#[derive(Clone, Debug)]
pub struct NetworkTopology {
    n: usize,
    /// Adjacency lists (no self loops stored; self weight is implicit).
    neighbors: Vec<Vec<usize>>,
    /// Metropolis weights aligned with `neighbors`, plus self weight.
    weights: Vec<Vec<f64>>,
    self_weights: Vec<f64>,
}

impl NetworkTopology {
    /// Build from an undirected edge list over `n` nodes.
    pub fn new(n: usize, edges: &[(usize, usize)]) -> Self {
        assert!(n > 0);
        let mut neighbors = vec![Vec::new(); n];
        for &(a, b) in edges {
            assert!(a < n && b < n && a != b, "invalid edge ({a},{b})");
            if !neighbors[a].contains(&b) {
                neighbors[a].push(b);
                neighbors[b].push(a);
            }
        }
        // Metropolis: a_lk = 1/(1+max(deg_l, deg_k)) for neighbors,
        // self weight = 1 − Σ_neighbors.
        let deg: Vec<usize> = neighbors.iter().map(|v| v.len()).collect();
        let mut weights = vec![Vec::new(); n];
        let mut self_weights = vec![0.0; n];
        for k in 0..n {
            let mut total = 0.0;
            for &l in &neighbors[k] {
                let w = 1.0 / (1.0 + deg[k].max(deg[l]) as f64);
                weights[k].push(w);
                total += w;
            }
            self_weights[k] = 1.0 - total;
        }
        Self { n, neighbors, weights, self_weights }
    }

    /// Ring of `n` nodes.
    pub fn ring(n: usize) -> Self {
        let edges: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        Self::new(n, &edges)
    }

    /// Fully connected graph of `n` nodes.
    pub fn complete(n: usize) -> Self {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                edges.push((i, j));
            }
        }
        Self::new(n, &edges)
    }

    /// Erdős–Rényi random graph (connected retries up to 100 draws).
    pub fn random(n: usize, p: f64, rng: &mut crate::rng::Rng) -> Self {
        for _ in 0..100 {
            let mut edges = Vec::new();
            for i in 0..n {
                for j in (i + 1)..n {
                    if rng.next_f64() < p {
                        edges.push((i, j));
                    }
                }
            }
            let topo = Self::new(n, &edges);
            if topo.is_connected() {
                return topo;
            }
        }
        // fall back to a ring (always connected)
        Self::ring(n)
    }

    /// Node count.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True for the empty network (never constructed; kept for API
    /// completeness).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Neighbors of node `k`.
    pub fn neighbors(&self, k: usize) -> &[usize] {
        &self.neighbors[k]
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(k) = stack.pop() {
            for &l in &self.neighbors[k] {
                if !seen[l] {
                    seen[l] = true;
                    count += 1;
                    stack.push(l);
                }
            }
        }
        count == self.n
    }

    /// Combination-matrix row sums must be 1 (doubly stochastic by
    /// Metropolis symmetry); exposed for tests.
    pub fn weight_row_sum(&self, k: usize) -> f64 {
        self.self_weights[k] + self.weights[k].iter().sum::<f64>()
    }
}

/// Diffusion RFF-KLMS: one θ per node, shared feature map (all nodes use
/// the same `(Ω, b)` — exactly what the fixed-size parameterization
/// enables: agreeing on a map costs one seed exchange).
pub struct DiffusionRffKlms {
    topo: NetworkTopology,
    map: RffMap,
    mu: f64,
    thetas: Vec<Vec<f64>>,
    /// scratch: combined estimates φ_k
    phi: Vec<Vec<f64>>,
    z: Vec<f64>,
}

impl DiffusionRffKlms {
    /// Build over `topo` with shared map and step size `mu`.
    pub fn new(topo: NetworkTopology, map: RffMap, mu: f64) -> Self {
        let n = topo.len();
        let d_feat = map.features();
        Self {
            topo,
            map,
            mu,
            thetas: vec![vec![0.0; d_feat]; n],
            phi: vec![vec![0.0; d_feat]; n],
            z: vec![0.0; d_feat],
        }
    }

    /// Node count.
    pub fn nodes(&self) -> usize {
        self.topo.len()
    }

    /// θ of node `k`.
    pub fn theta(&self, k: usize) -> &[f64] {
        &self.thetas[k]
    }

    /// Per-link payload in floats (the intro's point: D, not a dictionary).
    pub fn payload_floats(&self) -> usize {
        self.map.features()
    }

    /// One diffusion step: every node `k` receives its own sample
    /// `(x_k, y_k)`; combine-then-adapt; returns per-node a-priori errors
    /// (measured at the combined estimate φ_k, the standard convention).
    pub fn step(&mut self, samples: &[(Vec<f64>, f64)]) -> Vec<f64> {
        let n = self.topo.len();
        assert_eq!(samples.len(), n, "one sample per node");
        let d_feat = self.map.features();
        // combine
        for k in 0..n {
            let phi = &mut self.phi[k];
            phi.iter_mut().for_each(|v| *v = 0.0);
            axpy(self.topo.self_weights[k], &self.thetas[k], phi);
            for (idx, &l) in self.topo.neighbors[k].iter().enumerate() {
                axpy(self.topo.weights[k][idx], &self.thetas[l], phi);
            }
        }
        // adapt
        let mut errs = Vec::with_capacity(n);
        for k in 0..n {
            let (x, y) = &samples[k];
            self.map.apply_into(x, &mut self.z);
            let e = *y - dot(&self.phi[k], &self.z);
            let theta = &mut self.thetas[k];
            theta.copy_from_slice(&self.phi[k]);
            axpy(self.mu * e, &self.z, theta);
            errs.push(e);
            debug_assert_eq!(theta.len(), d_feat);
        }
        errs
    }

    /// Network disagreement: mean pairwise θ distance (convergence-to-
    /// consensus diagnostic).
    pub fn disagreement(&self) -> f64 {
        let n = self.topo.len();
        if n < 2 {
            return 0.0;
        }
        let mut acc = 0.0;
        let mut pairs = 0usize;
        for a in 0..n {
            for b in (a + 1)..n {
                acc += crate::linalg::sq_dist(&self.thetas[a], &self.thetas[b]).sqrt();
                pairs += 1;
            }
        }
        acc / pairs as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kaf::kernels::Kernel;
    use crate::rng::{run_rng, Distribution, Normal};
    use crate::signal::{NonlinearWiener, SignalSource};

    #[test]
    fn metropolis_rows_sum_to_one() {
        for topo in [
            NetworkTopology::ring(6),
            NetworkTopology::complete(5),
            NetworkTopology::new(4, &[(0, 1), (1, 2), (2, 3)]),
        ] {
            for k in 0..topo.len() {
                assert!((topo.weight_row_sum(k) - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn connectivity_checks() {
        assert!(NetworkTopology::ring(5).is_connected());
        assert!(!NetworkTopology::new(4, &[(0, 1), (2, 3)]).is_connected());
        let mut rng = run_rng(1, 0);
        assert!(NetworkTopology::random(8, 0.4, &mut rng).is_connected());
    }

    #[test]
    fn diffusion_beats_isolated_node_on_shared_task() {
        // All nodes observe the same underlying system with independent
        // noise; cooperation must reduce steady-state MSE vs. a single
        // no-neighbor node.
        let n_nodes = 8;
        let mut rng = run_rng(2, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 100);

        // shared clean system
        let mut sys = NonlinearWiener::new(run_rng(2, 1), 0.0);
        let horizon = 4000;
        let samples: Vec<_> = sys.take_samples(horizon);
        let noise = Normal::new(0.0, 0.5);

        let run = |topo: NetworkTopology, rng_seed: u64| -> f64 {
            let n = topo.len();
            let mut net = DiffusionRffKlms::new(topo, map.clone(), 0.5);
            let mut rng = run_rng(rng_seed, 2);
            let mut tail = 0.0;
            let mut count = 0;
            for (i, s) in samples.iter().enumerate() {
                let batch: Vec<(Vec<f64>, f64)> = (0..n)
                    .map(|_| (s.x.clone(), s.clean + noise.sample(&mut rng)))
                    .collect();
                let errs = net.step(&batch);
                if i >= horizon - 800 {
                    tail += errs.iter().map(|e| e * e).sum::<f64>() / n as f64;
                    count += 1;
                }
            }
            tail / count as f64
        };

        // compare EXCESS MSE over the sigma^2 = 0.25 noise floor: the
        // a-priori error always contains the fresh noise sample, which
        // cooperation cannot remove.
        let noise_floor = 0.25;
        let coop = run(NetworkTopology::complete(n_nodes), 3) - noise_floor;
        let solo = run(NetworkTopology::new(1, &[]), 3) - noise_floor;
        assert!(
            coop < solo * 0.75,
            "diffusion excess {coop} should clearly beat isolated excess {solo}"
        );
    }

    #[test]
    fn consensus_disagreement_shrinks() {
        let mut rng = run_rng(4, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 64);
        let mut net = DiffusionRffKlms::new(NetworkTopology::complete(5), map, 0.5);
        let mut sys = NonlinearWiener::new(run_rng(4, 1), 0.05);
        let mut early = 0.0;
        let mut late = 0.0;
        for i in 0..800 {
            let s = sys.next_sample();
            let batch: Vec<_> = (0..5).map(|_| (s.x.clone(), s.y)).collect();
            net.step(&batch);
            if i == 50 {
                early = net.disagreement();
            }
            if i == 799 {
                late = net.disagreement();
            }
        }
        assert!(late <= early * 1.5, "early={early} late={late}");
    }

    #[test]
    fn payload_is_d_not_dictionary() {
        let mut rng = run_rng(5, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 300);
        let net = DiffusionRffKlms::new(NetworkTopology::ring(3), map, 1.0);
        assert_eq!(net.payload_floats(), 300);
    }
}
