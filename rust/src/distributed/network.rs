//! Network topology + the diffusion network engine.
//!
//! [`NetworkTopology`] is an undirected graph with Metropolis
//! combination weights; [`DiffusionNetwork`] runs per-node RFF adaptive
//! filters over it, exchanging fixed-size `θ ∈ R^D` vectors — one node's
//! state per combine round, no dictionaries, no dictionary matching.
//!
//! ## Canonical adjacency order
//!
//! Adjacency lists are stored **sorted ascending and deduplicated**
//! (built through [`NetworkTopology::try_new`] regardless of the edge
//! list's order), and a node's combine accumulates `[self, neighbors
//! ascending]`. Floating-point combines are order-sensitive, so this
//! canonical order is what makes a topology reconstructed from
//! [`NetworkTopology::edges`] produce **bitwise-identical** diffusion
//! trajectories — the group snapshot round-trip guarantee rests on it.

use std::sync::Arc;

use anyhow::Result;

use crate::kaf::{RffMap, ROW_BLOCK};
use crate::linalg::simd;
use crate::linalg::{axpy, dot, seq_dot};

/// Undirected network topology with Metropolis combination weights
/// `a_lk = 1/(1 + max(deg_l, deg_k))` for neighbors and
/// `a_kk = 1 − Σ_l a_lk` — symmetric and doubly stochastic, the standard
/// choice of the diffusion-adaptation literature.
#[derive(Clone, Debug)]
pub struct NetworkTopology {
    n: usize,
    /// Adjacency lists in canonical (ascending, deduped) order; no self
    /// loops stored — the self weight is implicit.
    neighbors: Vec<Vec<usize>>,
    /// Metropolis weights aligned with `neighbors`, plus self weight.
    weights: Vec<Vec<f64>>,
    self_weights: Vec<f64>,
}

impl NetworkTopology {
    /// Build from an undirected edge list over `n` nodes, validating the
    /// edges (endpoints in range, no self loops; duplicates collapse).
    /// The stored adjacency is canonical regardless of `edges` order —
    /// see the module docs.
    pub fn try_new(n: usize, edges: &[(usize, usize)]) -> Result<Self> {
        anyhow::ensure!(n > 0, "a topology needs at least one node");
        let mut adj = vec![std::collections::BTreeSet::new(); n];
        for &(a, b) in edges {
            anyhow::ensure!(
                a < n && b < n,
                "edge ({a},{b}) is out of range for {n} nodes"
            );
            anyhow::ensure!(a != b, "self loop ({a},{a}) is not a valid edge");
            adj[a].insert(b);
            adj[b].insert(a);
        }
        let neighbors: Vec<Vec<usize>> =
            adj.into_iter().map(|s| s.into_iter().collect()).collect();
        // Metropolis: a_lk = 1/(1+max(deg_l, deg_k)) for neighbors,
        // self weight = 1 − Σ_neighbors.
        let deg: Vec<usize> = neighbors.iter().map(|v| v.len()).collect();
        let mut weights = vec![Vec::new(); n];
        let mut self_weights = vec![0.0; n];
        for k in 0..n {
            let mut total = 0.0;
            for &l in &neighbors[k] {
                let w = 1.0 / (1.0 + deg[k].max(deg[l]) as f64);
                weights[k].push(w);
                total += w;
            }
            self_weights[k] = 1.0 - total;
        }
        Ok(Self { n, neighbors, weights, self_weights })
    }

    /// [`Self::try_new`], panicking on an invalid edge list (programmatic
    /// construction; codecs and untrusted inputs use `try_new`).
    pub fn new(n: usize, edges: &[(usize, usize)]) -> Self {
        Self::try_new(n, edges).expect("valid topology")
    }

    /// Ring of `n` nodes.
    pub fn ring(n: usize) -> Self {
        let edges: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        Self::new(n, &edges)
    }

    /// Fully connected graph of `n` nodes.
    pub fn complete(n: usize) -> Self {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                edges.push((i, j));
            }
        }
        Self::new(n, &edges)
    }

    /// Path `0 — 1 — … — n−1`.
    pub fn path(n: usize) -> Self {
        let edges: Vec<(usize, usize)> = (1..n).map(|i| (i - 1, i)).collect();
        Self::new(n, &edges)
    }

    /// Connected Erdős–Rényi random graph: retries up to 100 draws and
    /// **errors** when none comes out connected, instead of silently
    /// handing back some other topology (it used to fall back to a ring,
    /// so callers could not know what graph they were actually running).
    pub fn random(n: usize, p: f64, rng: &mut crate::rng::Rng) -> Result<Self> {
        const ATTEMPTS: usize = 100;
        for _ in 0..ATTEMPTS {
            let mut edges = Vec::new();
            for i in 0..n {
                for j in (i + 1)..n {
                    if rng.next_f64() < p {
                        edges.push((i, j));
                    }
                }
            }
            let topo = Self::new(n, &edges);
            if topo.is_connected() {
                return Ok(topo);
            }
        }
        anyhow::bail!(
            "no connected Erdős–Rényi draw over {n} nodes at p = {p} in \
             {ATTEMPTS} attempts; raise p or pick an explicit topology"
        )
    }

    /// Node count.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True for the empty network (never constructed; kept for API
    /// completeness).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Neighbors of node `k`, in canonical ascending order.
    pub fn neighbors(&self, k: usize) -> &[usize] {
        &self.neighbors[k]
    }

    /// Degree of node `k`.
    pub fn degree(&self, k: usize) -> usize {
        self.neighbors[k].len()
    }

    /// Metropolis weight `a_lk` (self weight when `k == l`, 0 for
    /// non-neighbors). Symmetric: `weight(k, l) == weight(l, k)`.
    pub fn weight(&self, k: usize, l: usize) -> f64 {
        if k == l {
            return self.self_weights[k];
        }
        match self.neighbors[k].binary_search(&l) {
            Ok(pos) => self.weights[k][pos],
            Err(_) => 0.0,
        }
    }

    /// Self weight `a_kk`.
    pub fn self_weight(&self, k: usize) -> f64 {
        self.self_weights[k]
    }

    /// Neighbor weights of node `k`, aligned with [`Self::neighbors`].
    pub fn neighbor_weights(&self, k: usize) -> &[f64] {
        &self.weights[k]
    }

    /// Directed link count `Σ_k deg(k)` — the traffic-accounting unit of
    /// [`super::TrafficReport`] (each combine round ships one payload per
    /// directed link).
    pub fn links(&self) -> usize {
        self.neighbors.iter().map(|v| v.len()).sum()
    }

    /// The canonical undirected edge list (`a < b`, ascending). Feeding
    /// this back through [`Self::try_new`] reconstructs an identical
    /// topology — identical adjacency order, hence bitwise-identical
    /// combines (the snapshot codec's round-trip contract).
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for k in 0..self.n {
            for &l in &self.neighbors[k] {
                if k < l {
                    out.push((k, l));
                }
            }
        }
        out
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(k) = stack.pop() {
            for &l in &self.neighbors[k] {
                if !seen[l] {
                    seen[l] = true;
                    count += 1;
                    stack.push(l);
                }
            }
        }
        count == self.n
    }

    /// Combination-matrix row sums must be 1 (doubly stochastic by
    /// Metropolis symmetry); exposed for tests.
    pub fn weight_row_sum(&self, k: usize) -> f64 {
        self.self_weights[k] + self.weights[k].iter().sum::<f64>()
    }
}

/// Per-node adapt rule of a diffusion network. KRLS is deliberately
/// absent: its `P` matrix is per-node second-order state the diffusion
/// scheme does not combine — the exchanged quantity is θ alone.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DiffusionAlgo {
    /// RFF-KLMS adapt: `θ ← φ + μ e z`.
    Klms {
        /// LMS step size.
        mu: f64,
    },
    /// RFF-NLMS adapt: `θ ← φ + μ e z / (ε + ‖z‖²)`.
    Nlms {
        /// NLMS step size (μ ∈ (0, 2) for stability).
        mu: f64,
        /// Normalization regularizer.
        eps: f64,
    },
}

/// Which half-step runs first in a diffusion round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiffusionOrdering {
    /// Combine-then-adapt: `φ_k = Σ_l a_lk θ_l`, then
    /// `θ_k = φ_k + gain·z_k` with `e_k = y_k − φ_kᵀ z_k`.
    CombineThenAdapt,
    /// Adapt-then-combine (the Bouboulis et al. 2017 default — slightly
    /// better steady state because the combine averages *post-update*
    /// states): `ψ_k = θ_k + gain·z_k` with `e_k = y_k − θ_kᵀ z_k`, then
    /// `θ_k = Σ_l a_lk ψ_l`.
    AdaptThenCombine,
}

impl DiffusionOrdering {
    /// Stable codec name (`"cta"` / `"atc"`).
    pub fn name(self) -> &'static str {
        match self {
            DiffusionOrdering::CombineThenAdapt => "cta",
            DiffusionOrdering::AdaptThenCombine => "atc",
        }
    }

    /// Parse a codec name produced by [`Self::name`].
    pub fn from_name(name: &str) -> Result<Self> {
        match name {
            "cta" => Ok(DiffusionOrdering::CombineThenAdapt),
            "atc" => Ok(DiffusionOrdering::AdaptThenCombine),
            other => anyhow::bail!("unknown diffusion ordering '{other}'"),
        }
    }
}

/// A diffusion network: one θ per node over a shared frozen feature map
/// (the paper's "agree on a map costs one seed exchange" point — the
/// whole group holds exactly **one** `Arc<RffMap>`, interned via
/// [`MapRegistry`](crate::kaf::MapRegistry) when built from a spec).
///
/// Built batch-first on the crate's current substrate:
///
/// * The combine half-step runs the lane-oriented multi-axpy
///   ([`simd::weighted_combine_rows`]) over the node's `[self, neighbors
///   ascending]` term list — strict term-order accumulation, so combines
///   are reproducible bitwise across runs and restores.
/// * The feature map runs the blocked batch kernels
///   ([`RffMap::apply_batch_into`](crate::kaf::FeatureMap::apply_batch_into)) over whole windows of rounds; the
///   a-priori prediction is the strictly sequential
///   [`seq_dot`] — the same accumulation order as the fused
///   [`RffMap::apply_dot_into`](crate::kaf::FeatureMap::apply_dot_into), which is what makes [`Self::step_batch_into`]
///   **bitwise identical** to one [`Self::step_into`] per round
///   (property-tested in `tests/diffusion_parity.rs`).
/// * All scratches (the `[n, D]` combine stage, the blocked feature
///   block, the per-node term lists) are owned by the network and grown
///   once — steady-state steps allocate nothing.
pub struct DiffusionNetwork {
    topo: NetworkTopology,
    map: Arc<RffMap>,
    algo: DiffusionAlgo,
    ordering: DiffusionOrdering,
    /// Row-major `[n, D]` per-node weights.
    thetas: Vec<f64>,
    /// Per-node combine term rows: `[k, neighbors ascending]`, aligned
    /// with `combine_w`. Built once at construction.
    combine_idx: Vec<Vec<usize>>,
    /// Per-node combine weights: `[a_kk, a_lk …]`.
    combine_w: Vec<Vec<f64>>,
    /// `[n, D]` stage buffer: φ (combine-then-adapt) or ψ
    /// (adapt-then-combine) for the round in flight.
    stage: Vec<f64>,
    /// Blocked feature scratch (`[rounds_per_block · n, D]` max).
    zb: Vec<f64>,
}

impl DiffusionNetwork {
    /// Build over `topo` with a shared map (owned, or an `Arc` already
    /// interned in a registry), adapt rule and ordering.
    pub fn new(
        topo: NetworkTopology,
        map: impl Into<Arc<RffMap>>,
        algo: DiffusionAlgo,
        ordering: DiffusionOrdering,
    ) -> Self {
        match algo {
            DiffusionAlgo::Klms { mu } => assert!(mu > 0.0, "mu must be positive"),
            DiffusionAlgo::Nlms { mu, eps } => {
                assert!(mu > 0.0 && eps >= 0.0, "mu must be positive, eps non-negative")
            }
        }
        let map = map.into();
        assert!(
            !map.kind().is_adaptive(),
            "diffusion networks require a frozen map kind (got {}): every node \
             shares one (Ω, b) and exchanges θ only",
            map.kind().name()
        );
        let n = topo.len();
        let feats = map.features();
        let mut combine_idx = Vec::with_capacity(n);
        let mut combine_w = Vec::with_capacity(n);
        for k in 0..n {
            let mut idx = Vec::with_capacity(1 + topo.degree(k));
            let mut w = Vec::with_capacity(1 + topo.degree(k));
            idx.push(k);
            w.push(topo.self_weight(k));
            idx.extend_from_slice(topo.neighbors(k));
            w.extend_from_slice(topo.neighbor_weights(k));
            combine_idx.push(idx);
            combine_w.push(w);
        }
        Self {
            topo,
            map,
            algo,
            ordering,
            thetas: vec![0.0; n * feats],
            combine_idx,
            combine_w,
            stage: vec![0.0; n * feats],
            zb: Vec::new(),
        }
    }

    /// Node count.
    pub fn nodes(&self) -> usize {
        self.topo.len()
    }

    /// The network topology.
    pub fn topology(&self) -> &NetworkTopology {
        &self.topo
    }

    /// The shared feature map.
    pub fn map(&self) -> &RffMap {
        &self.map
    }

    /// The shared map handle — the group's **only** resident `(Ω, b)`.
    /// `Arc::strong_count` on it is independent of the node count.
    pub fn map_arc(&self) -> &Arc<RffMap> {
        &self.map
    }

    /// The per-node adapt rule.
    pub fn algo(&self) -> DiffusionAlgo {
        self.algo
    }

    /// The half-step ordering.
    pub fn ordering(&self) -> DiffusionOrdering {
        self.ordering
    }

    /// θ of node `k`.
    pub fn theta(&self, k: usize) -> &[f64] {
        let feats = self.map.features();
        &self.thetas[k * feats..(k + 1) * feats]
    }

    /// All per-node weights, row-major `[n, D]` (the snapshot payload).
    pub fn thetas(&self) -> &[f64] {
        &self.thetas
    }

    /// Network-mean θ — the consensus estimate the coordinator serves
    /// predictions from (per-node estimates agree with it up to the
    /// disagreement diagnostic once the network has converged).
    pub fn theta_mean(&self) -> Vec<f64> {
        let n = self.topo.len();
        let feats = self.map.features();
        let mut mean = vec![0.0; feats];
        for k in 0..n {
            axpy(1.0, &self.thetas[k * feats..(k + 1) * feats], &mut mean);
        }
        let inv = 1.0 / n as f64;
        for v in &mut mean {
            *v *= inv;
        }
        mean
    }

    /// Overwrite every node's θ (snapshot restore). `thetas` must be
    /// row-major `[n, D]`.
    pub fn restore_thetas(&mut self, thetas: Vec<f64>) {
        assert_eq!(thetas.len(), self.thetas.len(), "thetas must be [n, D]");
        self.thetas = thetas;
    }

    /// Node `k`'s prediction `ŷ = θ_kᵀ z_Ω(x)` — Z-free fused kernel,
    /// no allocation.
    pub fn predict(&self, k: usize, x: &[f64]) -> f64 {
        let mut out = [0.0];
        self.map.predict_batch_into(x, self.theta(k), &mut out);
        out[0]
    }

    /// Per-link payload in floats (the intro's point: D, not a dictionary).
    pub fn payload_floats(&self) -> usize {
        self.map.features()
    }

    /// One diffusion round: node `k` receives row `k` of the row-major
    /// `[n, d]` window `xs` with target `ys[k]`; `errs` (length `n`)
    /// receives the a-priori errors (measured at φ_k under
    /// combine-then-adapt, at θ_k under adapt-then-combine — the
    /// standard conventions). Allocation-free at steady state.
    pub fn step_into(&mut self, xs: &[f64], ys: &[f64], errs: &mut [f64]) {
        assert_eq!(ys.len(), self.topo.len(), "step takes exactly one sample per node");
        self.step_batch_into(xs, ys, errs);
    }

    /// [`Self::step_into`], allocating the error vector.
    pub fn step(&mut self, xs: &[f64], ys: &[f64]) -> Vec<f64> {
        let mut errs = vec![0.0; ys.len()];
        self.step_into(xs, ys, &mut errs);
        errs
    }

    /// A whole window of rounds in one call: `xs` is row-major
    /// `[rounds · n, d]` (round-major — round `r`'s node `k` is row
    /// `r·n + k`), `ys`/`errs` match. The feature map runs the blocked
    /// batch kernels over up to `max(1, ROW_BLOCK / n)` rounds at a time
    /// (each `ω`/`b` lane loads once per block and serves every row);
    /// combines and adapts stay strictly sequential in round order, so
    /// the result is **bitwise identical** to one [`Self::step_into`]
    /// call per round — `tests/diffusion_parity.rs` pins this at node
    /// and row counts coprime with `LANES`/`ROW_BLOCK`.
    pub fn step_batch_into(&mut self, xs: &[f64], ys: &[f64], errs: &mut [f64]) {
        let n = self.topo.len();
        let d = self.map.dim();
        let feats = self.map.features();
        assert_eq!(
            ys.len() % n,
            0,
            "step_batch rows must be whole rounds of {n} nodes"
        );
        assert_eq!(xs.len(), ys.len() * d, "xs must be row-major [rows, d]");
        assert_eq!(errs.len(), ys.len(), "errs must have one slot per row");
        if ys.is_empty() {
            return;
        }
        let rounds = ys.len() / n;
        let rounds_per_block = (ROW_BLOCK / n).max(1);
        let need = rounds_per_block.min(rounds) * n * feats;
        if self.zb.len() < need {
            self.zb.resize(need, 0.0);
        }
        let mut r0 = 0;
        while r0 < rounds {
            let rb = rounds_per_block.min(rounds - r0);
            let rows = rb * n;
            let row0 = r0 * n;
            self.map
                .apply_batch_into(&xs[row0 * d..(row0 + rows) * d], &mut self.zb[..rows * feats]);
            for r in 0..rb {
                let lo = (r0 + r) * n;
                self.round_core(r * n, &ys[lo..lo + n], &mut errs[lo..lo + n]);
            }
            r0 += rb;
        }
    }

    /// [`Self::step_batch_into`], allocating the error vector.
    pub fn step_batch(&mut self, xs: &[f64], ys: &[f64]) -> Vec<f64> {
        let mut errs = vec![0.0; ys.len()];
        self.step_batch_into(xs, ys, &mut errs);
        errs
    }

    /// The adapt gain for error `e` at features `z`.
    #[inline]
    fn gain(algo: DiffusionAlgo, e: f64, z: &[f64]) -> f64 {
        match algo {
            DiffusionAlgo::Klms { mu } => mu * e,
            DiffusionAlgo::Nlms { mu, eps } => mu * e / (eps + dot(z, z)),
        }
    }

    /// One combine+adapt round over the `n` feature rows starting at
    /// `zb` row `zrow0`. The single round implementation both
    /// [`Self::step_into`] and [`Self::step_batch_into`] run — one code
    /// path, so per-step and windowed training cannot diverge.
    fn round_core(&mut self, zrow0: usize, ys: &[f64], errs: &mut [f64]) {
        let n = self.topo.len();
        let feats = self.map.features();
        match self.ordering {
            DiffusionOrdering::CombineThenAdapt => {
                // combine: φ_k = Σ_l a_lk θ_l (lane multi-axpy, strict
                // [self, neighbors ascending] term order)
                for k in 0..n {
                    simd::weighted_combine_rows(
                        feats,
                        &self.thetas,
                        &self.combine_idx[k],
                        &self.combine_w[k],
                        &mut self.stage[k * feats..(k + 1) * feats],
                    );
                }
                // adapt from φ: θ_k = φ_k + gain·z_k
                for k in 0..n {
                    let z = &self.zb[(zrow0 + k) * feats..(zrow0 + k + 1) * feats];
                    let phi = &self.stage[k * feats..(k + 1) * feats];
                    let e = ys[k] - seq_dot(phi, z);
                    let g = Self::gain(self.algo, e, z);
                    let theta = &mut self.thetas[k * feats..(k + 1) * feats];
                    theta.copy_from_slice(phi);
                    axpy(g, z, theta);
                    errs[k] = e;
                }
            }
            DiffusionOrdering::AdaptThenCombine => {
                // adapt: ψ_k = θ_k + gain·z_k, error at θ_k
                for k in 0..n {
                    let z = &self.zb[(zrow0 + k) * feats..(zrow0 + k + 1) * feats];
                    let theta = &self.thetas[k * feats..(k + 1) * feats];
                    let e = ys[k] - seq_dot(theta, z);
                    let g = Self::gain(self.algo, e, z);
                    let psi = &mut self.stage[k * feats..(k + 1) * feats];
                    psi.copy_from_slice(theta);
                    axpy(g, z, psi);
                    errs[k] = e;
                }
                // combine: θ_k = Σ_l a_lk ψ_l
                for k in 0..n {
                    simd::weighted_combine_rows(
                        feats,
                        &self.stage,
                        &self.combine_idx[k],
                        &self.combine_w[k],
                        &mut self.thetas[k * feats..(k + 1) * feats],
                    );
                }
            }
        }
    }

    /// Network disagreement: mean pairwise θ distance (convergence-to-
    /// consensus diagnostic).
    pub fn disagreement(&self) -> f64 {
        let n = self.topo.len();
        if n < 2 {
            return 0.0;
        }
        let feats = self.map.features();
        let mut acc = 0.0;
        let mut pairs = 0usize;
        for a in 0..n {
            for b in (a + 1)..n {
                acc += crate::linalg::sq_dist(
                    &self.thetas[a * feats..(a + 1) * feats],
                    &self.thetas[b * feats..(b + 1) * feats],
                )
                .sqrt();
                pairs += 1;
            }
        }
        acc / pairs as f64
    }

    /// Approximate heap bytes of the group's **own** state — per-node θ,
    /// the combine stage, feature scratch and term lists — excluding the
    /// shared map (count that once per fleet via [`RffMap::heap_bytes`](crate::kaf::FeatureMap::heap_bytes)).
    pub fn heap_bytes(&self) -> usize {
        let terms: usize = self
            .combine_idx
            .iter()
            .zip(&self.combine_w)
            .map(|(i, w)| i.capacity() * 8 + w.capacity() * 8)
            .sum();
        (self.thetas.len() + self.stage.len() + self.zb.capacity()) * 8 + terms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kaf::kernels::Kernel;
    use crate::kaf::{OnlineRegressor, RffKlms};
    use crate::rng::{run_rng, Distribution, Normal};
    use crate::signal::{NonlinearWiener, SignalSource};

    fn flat_round(x: &[f64], y: f64, n: usize) -> (Vec<f64>, Vec<f64>) {
        let mut xs = Vec::with_capacity(n * x.len());
        for _ in 0..n {
            xs.extend_from_slice(x);
        }
        (xs, vec![y; n])
    }

    #[test]
    fn metropolis_rows_sum_to_one_and_weights_are_symmetric() {
        // satellite: not just ring/complete/path — random graphs too
        let mut rng = run_rng(1, 0);
        let mut topos = vec![
            NetworkTopology::ring(6),
            NetworkTopology::complete(5),
            NetworkTopology::path(4),
        ];
        for draw in 0..4 {
            topos.push(NetworkTopology::random(7 + draw, 0.5, &mut rng).unwrap());
        }
        for topo in &topos {
            for k in 0..topo.len() {
                assert!(
                    (topo.weight_row_sum(k) - 1.0).abs() < 1e-12,
                    "row {k} sums to {}",
                    topo.weight_row_sum(k)
                );
                for l in 0..topo.len() {
                    assert_eq!(
                        topo.weight(k, l),
                        topo.weight(l, k),
                        "Metropolis weights must be symmetric ({k},{l})"
                    );
                    if k != l && !topo.neighbors(k).contains(&l) {
                        assert_eq!(topo.weight(k, l), 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn adjacency_is_canonical_regardless_of_edge_order() {
        // scrambled, duplicated edge lists build the identical topology
        let a = NetworkTopology::new(5, &[(0, 3), (1, 0), (2, 4), (3, 2), (0, 3)]);
        let b = NetworkTopology::new(5, &[(3, 0), (4, 2), (0, 1), (2, 3)]);
        assert_eq!(a.edges(), b.edges());
        for k in 0..5 {
            assert_eq!(a.neighbors(k), b.neighbors(k));
            assert_eq!(a.neighbor_weights(k), b.neighbor_weights(k));
        }
        // edges() round-trips through try_new
        let c = NetworkTopology::try_new(5, &a.edges()).unwrap();
        for k in 0..5 {
            assert_eq!(a.neighbors(k), c.neighbors(k));
        }
        assert_eq!(a.links(), 8); // 4 undirected edges = 8 directed links
    }

    #[test]
    fn invalid_edges_are_diagnostic_errors() {
        assert!(NetworkTopology::try_new(0, &[]).is_err());
        let err = NetworkTopology::try_new(4, &[(0, 7)]).unwrap_err().to_string();
        assert!(err.contains("out of range"), "unhelpful error: {err}");
        let err = NetworkTopology::try_new(4, &[(2, 2)]).unwrap_err().to_string();
        assert!(err.contains("self loop"), "unhelpful error: {err}");
    }

    #[test]
    fn connectivity_checks() {
        assert!(NetworkTopology::ring(5).is_connected());
        assert!(!NetworkTopology::new(4, &[(0, 1), (2, 3)]).is_connected());
        let mut rng = run_rng(1, 0);
        assert!(NetworkTopology::random(8, 0.4, &mut rng).unwrap().is_connected());
    }

    #[test]
    fn random_surfaces_unconnected_draws_instead_of_ring_fallback() {
        // regression: p = 0 can never produce a connected graph on n ≥ 2;
        // the old code silently handed back a ring here
        let mut rng = run_rng(2, 0);
        let err = NetworkTopology::random(6, 0.0, &mut rng).unwrap_err().to_string();
        assert!(err.contains("no connected"), "unhelpful error: {err}");
        // a single node with no edges is trivially connected
        assert!(NetworkTopology::random(1, 0.0, &mut rng).is_ok());
    }

    #[test]
    fn solo_group_matches_rffklms_bitwise() {
        // a 1-node network combines with weight a_00 = 1 and adapts with
        // the same expressions as the plain filter: exact agreement
        let mut rng = run_rng(3, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 37);
        let mut filter = RffKlms::new(map.clone(), 0.5);
        let mut net = DiffusionNetwork::new(
            NetworkTopology::new(1, &[]),
            map,
            DiffusionAlgo::Klms { mu: 0.5 },
            DiffusionOrdering::CombineThenAdapt,
        );
        let mut src = NonlinearWiener::new(run_rng(3, 1), 0.05);
        for s in src.take_samples(200) {
            let want = filter.step(&s.x, s.y);
            let got = net.step(&s.x, &[s.y]);
            assert_eq!(got, vec![want], "solo diffusion node diverged from RffKlms");
        }
        assert_eq!(net.theta(0), filter.theta());
    }

    #[test]
    fn diffusion_beats_isolated_node_on_shared_task() {
        // All nodes observe the same underlying system with independent
        // noise; cooperation must reduce steady-state MSE vs. a single
        // no-neighbor node.
        let n_nodes = 8;
        let mut rng = run_rng(2, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 100);

        // shared clean system
        let mut sys = NonlinearWiener::new(run_rng(2, 1), 0.0);
        let horizon = 4000;
        let samples: Vec<_> = sys.take_samples(horizon);
        let noise = Normal::new(0.0, 0.5);

        let run = |topo: NetworkTopology, rng_seed: u64| -> f64 {
            let n = topo.len();
            let mut net = DiffusionNetwork::new(
                topo,
                map.clone(),
                DiffusionAlgo::Klms { mu: 0.5 },
                DiffusionOrdering::CombineThenAdapt,
            );
            let mut rng = run_rng(rng_seed, 2);
            let mut errs = vec![0.0; n];
            let mut xs = vec![0.0; n * 5];
            let mut ys = vec![0.0; n];
            let mut tail = 0.0;
            let mut count = 0;
            for (i, s) in samples.iter().enumerate() {
                for k in 0..n {
                    xs[k * 5..(k + 1) * 5].copy_from_slice(&s.x);
                    ys[k] = s.clean + noise.sample(&mut rng);
                }
                net.step_into(&xs, &ys, &mut errs);
                if i >= horizon - 800 {
                    tail += errs.iter().map(|e| e * e).sum::<f64>() / n as f64;
                    count += 1;
                }
            }
            tail / count as f64
        };

        // compare EXCESS MSE over the sigma^2 = 0.25 noise floor: the
        // a-priori error always contains the fresh noise sample, which
        // cooperation cannot remove.
        let noise_floor = 0.25;
        let coop = run(NetworkTopology::complete(n_nodes), 3) - noise_floor;
        let solo = run(NetworkTopology::new(1, &[]), 3) - noise_floor;
        assert!(
            coop < solo * 0.75,
            "diffusion excess {coop} should clearly beat isolated excess {solo}"
        );
    }

    #[test]
    fn atc_and_nlms_variants_learn() {
        // convergence smoke for the adapt-then-combine ordering and the
        // NLMS adapt rule
        for (algo, ordering) in [
            (DiffusionAlgo::Klms { mu: 0.5 }, DiffusionOrdering::AdaptThenCombine),
            (
                DiffusionAlgo::Nlms { mu: 0.5, eps: 1e-6 },
                DiffusionOrdering::AdaptThenCombine,
            ),
            (
                DiffusionAlgo::Nlms { mu: 0.5, eps: 1e-6 },
                DiffusionOrdering::CombineThenAdapt,
            ),
        ] {
            let mut rng = run_rng(5, 0);
            let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 100);
            let mut net =
                DiffusionNetwork::new(NetworkTopology::ring(4), map, algo, ordering);
            let mut sys = NonlinearWiener::new(run_rng(5, 1), 0.05);
            let mut head = 0.0;
            let mut tail = 0.0;
            for i in 0..1500 {
                let s = sys.next_sample();
                let (xs, ys) = flat_round(&s.x, s.y, 4);
                let errs = net.step(&xs, &ys);
                let mse = errs.iter().map(|e| e * e).sum::<f64>() / 4.0;
                if i < 150 {
                    head += mse;
                }
                if i >= 1350 {
                    tail += mse;
                }
            }
            assert!(
                tail < head * 0.5,
                "{algo:?}/{ordering:?} did not learn: head {head} tail {tail}"
            );
        }
    }

    #[test]
    fn consensus_disagreement_shrinks() {
        let mut rng = run_rng(4, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 64);
        let mut net = DiffusionNetwork::new(
            NetworkTopology::complete(5),
            map,
            DiffusionAlgo::Klms { mu: 0.5 },
            DiffusionOrdering::CombineThenAdapt,
        );
        let mut sys = NonlinearWiener::new(run_rng(4, 1), 0.05);
        let mut noise_rng = run_rng(4, 2);
        let noise = Normal::new(0.0, 0.3);
        let mut early = 0.0;
        let mut late = 0.0;
        for i in 0..800 {
            let s = sys.next_sample();
            let mut xs = Vec::with_capacity(5 * 5);
            let mut ys = Vec::with_capacity(5);
            for _ in 0..5 {
                xs.extend_from_slice(&s.x);
                ys.push(s.y + noise.sample(&mut noise_rng));
            }
            net.step(&xs, &ys);
            if i == 50 {
                early = net.disagreement();
            }
            if i == 799 {
                late = net.disagreement();
            }
        }
        assert!(late <= early * 1.5, "early={early} late={late}");
    }

    #[test]
    fn complete_graph_zero_noise_stays_in_exact_consensus() {
        // satellite: with identical observations and a complete graph the
        // per-node updates are identical, so disagreement is exactly 0 —
        // not merely small
        let mut rng = run_rng(6, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 48);
        let mut net = DiffusionNetwork::new(
            NetworkTopology::complete(6),
            map,
            DiffusionAlgo::Klms { mu: 0.5 },
            DiffusionOrdering::AdaptThenCombine,
        );
        let mut sys = NonlinearWiener::new(run_rng(6, 1), 0.0);
        for s in sys.take_samples(600) {
            let (xs, ys) = flat_round(&s.x, s.y, 6);
            net.step(&xs, &ys);
            assert_eq!(net.disagreement(), 0.0, "consensus broke under zero noise");
        }
        // and the consensus estimate actually learned something
        let mut probe_sys = NonlinearWiener::new(run_rng(6, 1), 0.0);
        let probes = probe_sys.take_samples(610);
        let mse: f64 = probes[600..]
            .iter()
            .map(|s| (net.predict(0, &s.x) - s.clean).powi(2))
            .sum::<f64>()
            / 10.0;
        assert!(mse < 1.0, "consensus model mse {mse}");
    }

    #[test]
    fn payload_is_d_not_dictionary() {
        let mut rng = run_rng(5, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 300);
        let net = DiffusionNetwork::new(
            NetworkTopology::ring(3),
            map,
            DiffusionAlgo::Klms { mu: 1.0 },
            DiffusionOrdering::CombineThenAdapt,
        );
        assert_eq!(net.payload_floats(), 300);
        // one resident map for the whole group
        assert_eq!(std::sync::Arc::strong_count(net.map_arc()), 1);
    }
}
