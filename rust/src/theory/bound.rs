//! The Rahimi–Recht uniform approximation bound (Claim 1 of "Random
//! Features for Large-Scale Kernel Machines"), which the paper's §3
//! invokes for "details on the quality of this approximation".
//!
//! For the Gaussian kernel on a compact set of diameter `diam`:
//!
//! `P( sup |z(x)ᵀz(y) − κ(x−y)| ≥ ε ) ≤ 2⁸ (σ_p diam / ε)² exp(−D ε² / (4(d+2)))`
//!
//! with `σ_p² = E‖ω‖² = d/σ²` for bandwidth σ. We expose the bound, the
//! D required to certify a target (ε, δ), and an empirical max-error
//! estimator used by the ablation tests.

use crate::kaf::kernels::Kernel;
use crate::kaf::RffMap;
use crate::rng::{Distribution, Normal, Rng};

/// The right-hand side of the uniform bound (may exceed 1 = vacuous).
pub fn uniform_error_bound(d: usize, features: usize, sigma: f64, diam: f64, eps: f64) -> f64 {
    assert!(eps > 0.0 && sigma > 0.0 && diam > 0.0);
    let sigma_p = (d as f64).sqrt() / sigma;
    let prefactor = 2f64.powi(8) * (sigma_p * diam / eps).powi(2);
    let exponent = -(features as f64) * eps * eps / (4.0 * (d as f64 + 2.0));
    (prefactor * exponent.exp()).min(1.0)
}

/// Smallest D certifying `sup error ≤ eps` with probability `1 − delta`
/// (inverting the bound; the paper's "sufficiently large D").
pub fn required_features(d: usize, sigma: f64, diam: f64, eps: f64, delta: f64) -> usize {
    assert!((0.0..1.0).contains(&delta) && delta > 0.0);
    let sigma_p = (d as f64).sqrt() / sigma;
    let prefactor = 2f64.powi(8) * (sigma_p * diam / eps).powi(2);
    let needed = 4.0 * (d as f64 + 2.0) / (eps * eps) * (prefactor / delta).ln();
    needed.ceil().max(1.0) as usize
}

/// Empirical max kernel-approximation error of `map` over `n` random
/// pairs drawn from `N(0, (diam/4)² I)` (so pairs span ~the diameter).
pub fn empirical_max_error(
    map: &RffMap,
    kernel: Kernel,
    diam: f64,
    n: usize,
    rng: &mut Rng,
) -> f64 {
    let normal = Normal::new(0.0, diam / 4.0);
    let mut worst = 0.0f64;
    for _ in 0..n {
        let x: Vec<f64> = normal.sample_vec(rng, map.dim());
        let y: Vec<f64> = normal.sample_vec(rng, map.dim());
        let err = (map.approx_kernel(&x, &y) - kernel.eval(&x, &y)).abs();
        worst = worst.max(err);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::run_rng;

    #[test]
    fn bound_decreases_with_d_and_increases_with_precision() {
        // the bound is loose: it only becomes non-vacuous at large D
        let b1 = uniform_error_bound(5, 50_000, 5.0, 4.0, 0.1);
        let b2 = uniform_error_bound(5, 100_000, 5.0, 4.0, 0.1);
        assert!(b1 < 1.0, "bound vacuous at D=50k: {b1}");
        assert!(b2 < b1, "{b2} !< {b1}");
        let b3 = uniform_error_bound(5, 50_000, 5.0, 4.0, 0.01);
        assert!(b3 >= b1);
    }

    #[test]
    fn required_features_is_consistent_with_bound() {
        let (d, sigma, diam, eps, delta) = (5usize, 5.0, 4.0, 0.1, 0.05);
        let need = required_features(d, sigma, diam, eps, delta);
        let at_need = uniform_error_bound(d, need, sigma, diam, eps);
        assert!(at_need <= delta * 1.01, "bound {at_need} at D={need}");
        let below = uniform_error_bound(d, need / 2, sigma, diam, eps);
        assert!(below > at_need);
    }

    #[test]
    fn empirical_error_within_certified_eps() {
        // Certify eps=0.25 at 95% for d=3, sigma=2, diam=4; draw that D
        // and verify the empirical max error over 2000 pairs obeys it
        // (overwhelmingly likely since the bound is loose).
        let (d, sigma, diam, eps, delta) = (3usize, 2.0, 4.0, 0.25, 0.05);
        let need = required_features(d, sigma, diam, eps, delta);
        let kernel = Kernel::Gaussian { sigma };
        let mut rng = run_rng(7, 0);
        let map = RffMap::draw(&mut rng, kernel, d, need);
        let worst = empirical_max_error(&map, kernel, diam, 2000, &mut rng);
        assert!(worst < eps, "empirical {worst} vs certified {eps} (D={need})");
    }

    #[test]
    fn empirical_error_shrinks_with_d() {
        let kernel = Kernel::Gaussian { sigma: 2.0 };
        let mut rng = run_rng(8, 0);
        let small = RffMap::draw(&mut rng, kernel, 3, 32);
        let big = RffMap::draw(&mut rng, kernel, 3, 4096);
        let e_small = empirical_max_error(&small, kernel, 4.0, 500, &mut rng);
        let e_big = empirical_max_error(&big, kernel, 4.0, 500, &mut rng);
        assert!(e_big < e_small, "{e_big} !< {e_small}");
    }
}
