//! The paper's §4 mean-square analysis, made executable.
//!
//! * [`rzz_closed_form`] — the exact entries of
//!   `R_zz = E[z_Ω(x) z_Ω(x)ᵀ]` for `x ~ N(0, σ_x² I)` (the displayed
//!   `r_{i,j}` formula of §4).
//! * [`rzz_empirical`] — Monte-Carlo estimate (validates the formula).
//! * [`spd_certificate`] — Lemma 1 check via Cholesky.
//! * [`step_size_bounds`] — Proposition 1.1/1.4: `μ < 2/λ_max` (mean),
//!   `μ < 1/λ_max` (mean-square).
//! * [`optimal_theta`] — Eq. (8) with the `η'` correction dropped
//!   (the paper argues it vanishes for large D).
//! * [`uniform_error_bound`] / [`required_features`] — the Rahimi–Recht
//!   uniform approximation bound the paper's §3 cites.
//! * [`predicted_learning_curve`] / [`steady_state_mse`] — the A_n
//!   recursion of Proposition 1.4 in the eigenbasis of `R_zz` (O(D) per
//!   step instead of O(D³)), regenerating Fig. 1's dashed line.

mod bound;
mod rzz;
mod steady_state;

pub use bound::{empirical_max_error, required_features, uniform_error_bound};
pub use rzz::{rzz_closed_form, rzz_empirical, spd_certificate};
pub use steady_state::{
    optimal_theta, predicted_learning_curve, steady_state_mse, StepSizeBounds,
};

use crate::linalg::{symmetric_eigenvalues, Mat};

/// Step-size bounds from the spectrum of `R_zz` (Proposition 1).
pub fn step_size_bounds(rzz: &Mat) -> StepSizeBounds {
    let ev = symmetric_eigenvalues(rzz);
    let lambda_max = *ev.last().unwrap();
    let lambda_min = ev[0];
    StepSizeBounds {
        mean_stable: 2.0 / lambda_max,
        mean_square_stable: 1.0 / lambda_max,
        lambda_min,
        lambda_max,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kaf::kernels::Kernel;
    use crate::kaf::RffMap;
    use crate::rng::run_rng;

    #[test]
    fn bounds_ordered() {
        let mut rng = run_rng(1, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 32);
        let r = rzz_closed_form(&map, 1.0);
        let b = step_size_bounds(&r);
        assert!(b.lambda_min > 0.0, "Lemma 1: R_zz strictly PD");
        assert!(b.mean_square_stable < b.mean_stable);
        assert!((b.mean_stable - 2.0 * b.mean_square_stable).abs() < 1e-12);
    }

    #[test]
    fn paper_mu_one_is_stable_for_ex1_config() {
        // The paper uses mu=1 for Ex.1 (sigma=5, D up to large): check
        // mu=1 < 2/lambda_max indeed holds for a representative draw.
        let mut rng = run_rng(2, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 100);
        let r = rzz_closed_form(&map, 1.0);
        let b = step_size_bounds(&r);
        assert!(
            b.mean_stable > 1.0,
            "mu=1 must satisfy Theorem requirements (bound {})",
            b.mean_stable
        );
    }
}
