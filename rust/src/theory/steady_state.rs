//! Proposition 1 made executable: the optimal solution (Eq. 8), the A_n
//! weight-error-covariance recursion, the predicted transient learning
//! curve and the steady-state MSE — the dashed line of Fig. 1.

use crate::kaf::RffMap;
use crate::linalg::{symmetric_eigen, Mat};

/// Step-size stability bounds (Proposition 1.1 and 1.4).
#[derive(Clone, Copy, Debug)]
pub struct StepSizeBounds {
    /// Convergence in the mean requires `μ < mean_stable = 2/λ_max`.
    pub mean_stable: f64,
    /// Convergence of `A_n` (mean square) requires `μ < 1/λ_max`.
    pub mean_square_stable: f64,
    /// Smallest eigenvalue of `R_zz` (governs slowest mode).
    pub lambda_min: f64,
    /// Largest eigenvalue of `R_zz`.
    pub lambda_max: f64,
}

/// Eq. (8) with the `η'` correction dropped (valid for large D):
/// `θ_opt ≈ Σ_m a_m z_Ω(c_m)`.
///
/// `centers` are the expansion centers `c_m` of the data model (7),
/// `coeffs` the `a_m`.
pub fn optimal_theta(map: &RffMap, centers: &[Vec<f64>], coeffs: &[f64]) -> Vec<f64> {
    assert_eq!(centers.len(), coeffs.len());
    let mut theta = vec![0.0; map.features()];
    let mut z = vec![0.0; map.features()];
    for (c, &a) in centers.iter().zip(coeffs) {
        map.apply_into(c, &mut z);
        crate::linalg::axpy(a, &z, &mut theta);
    }
    theta
}

/// Steady-state MSE from Proposition 1.4.
///
/// In steady state the Lyapunov recursion
/// `A_{n+1} = A_n − μ(R A_n + A_n R) + μ² σ_η² R`
/// fixes `A_ss = (μ σ_η²/2) I`, giving
/// `J_ss ≈ σ_η² + tr(R_zz A_ss) = σ_η² (1 + (μ/2) tr(R_zz))`.
pub fn steady_state_mse(rzz: &Mat, mu: f64, noise_var: f64) -> f64 {
    noise_var * (1.0 + 0.5 * mu * rzz.trace())
}

/// The full predicted learning curve `J_n = J_opt + tr(R_zz A_n)` for
/// `n = 0..horizon`, computed in the eigenbasis of `R_zz` where the
/// recursion diagonalizes:
///
/// `ã_i(n+1) = (1 − 2μλ_i) ã_i(n) + μ² σ_η² λ_i`, with
/// `ã_i(0) = (Vᵀ θ_opt)_i²` (filter initialised at θ=0), and
/// `J_n^ex = Σ_i λ_i ã_i(n)`.
///
/// Off-diagonal terms of `Ã` do not enter `tr(Λ Ã)` and decay
/// geometrically, so tracking the diagonal is exact for the reported
/// curve. O(D) per step.
pub fn predicted_learning_curve(
    rzz: &Mat,
    theta_opt: &[f64],
    mu: f64,
    noise_var: f64,
    horizon: usize,
) -> Vec<f64> {
    let eig = symmetric_eigen(rzz, 128);
    let d_feat = rzz.rows();
    assert_eq!(theta_opt.len(), d_feat);
    // project theta_opt on the eigenbasis: (Vᵀ θ)_i
    let mut a_diag = vec![0.0; d_feat];
    for i in 0..d_feat {
        let mut proj = 0.0;
        for k in 0..d_feat {
            proj += eig.eigenvectors[(k, i)] * theta_opt[k];
        }
        a_diag[i] = proj * proj;
    }
    let lam = &eig.eigenvalues;
    let mut curve = Vec::with_capacity(horizon);
    for _ in 0..horizon {
        let jex: f64 = lam.iter().zip(&a_diag).map(|(&l, &a)| l * a).sum();
        curve.push(noise_var + jex);
        for (a, &l) in a_diag.iter_mut().zip(lam.iter()) {
            *a = (1.0 - 2.0 * mu * l) * *a + mu * mu * noise_var * l;
        }
    }
    curve
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kaf::kernels::Kernel;
    use crate::rng::run_rng;
    use crate::theory::rzz_closed_form;

    fn setup(d_feat: usize) -> (RffMap, Mat) {
        let mut rng = run_rng(1, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, d_feat);
        let r = rzz_closed_form(&map, 1.0);
        (map, r)
    }

    #[test]
    fn curve_starts_high_and_decays_to_steady_state() {
        let (map, r) = setup(48);
        let centers: Vec<Vec<f64>> = (0..6)
            .map(|i| (0..5).map(|k| ((i * 5 + k) as f64 * 0.37).sin()).collect())
            .collect();
        let coeffs: Vec<f64> = (0..6).map(|i| (i as f64 - 2.5) * 1.7).collect();
        let theta = optimal_theta(&map, &centers, &coeffs);
        let noise_var = 0.01;
        let mu = 0.5;
        let curve = predicted_learning_curve(&r, &theta, mu, noise_var, 8000);
        assert!(curve[0] > curve[7999], "no decay");
        let ss = steady_state_mse(&r, mu, noise_var);
        let tail = curve[7900..].iter().sum::<f64>() / 100.0;
        assert!(
            (tail - ss).abs() / ss < 0.2,
            "recursion tail {tail} vs closed-form steady state {ss}"
        );
        // steady state above the noise floor but same order
        assert!(ss > noise_var && ss < 3.0 * noise_var);
    }

    #[test]
    fn stable_mu_converges_to_floor() {
        // For mu < 1/lambda_max every mode's |1-2 mu lambda_i| < 1, so the
        // curve converges; it need not be monotone (modes with
        // mu*lambda > 1/2 oscillate), so we check convergence + bound.
        let (map, r) = setup(32);
        let theta = optimal_theta(&map, &[vec![0.5; 5]], &[2.0]);
        let b = crate::theory::step_size_bounds(&r);
        let mu = 0.5 * b.mean_square_stable;
        let curve = predicted_learning_curve(&r, &theta, mu, 0.01, 3000);
        assert!(curve.iter().all(|v| v.is_finite()));
        let tail = curve[2900..].iter().sum::<f64>() / 100.0;
        let head = curve[..10].iter().sum::<f64>() / 10.0;
        assert!(tail < head, "no net decay: head {head} tail {tail}");
        // tail settled: last two windows agree to 1%
        let prev = curve[2800..2900].iter().sum::<f64>() / 100.0;
        assert!((tail - prev).abs() / tail < 0.01);
    }

    #[test]
    fn unstable_mu_diverges() {
        let (map, r) = setup(32);
        let theta = optimal_theta(&map, &[vec![0.5; 5]], &[2.0]);
        let b = crate::theory::step_size_bounds(&r);
        let mu = 1.5 * b.mean_stable; // beyond 2/lambda_max
        let curve = predicted_learning_curve(&r, &theta, mu, 0.01, 2000);
        // the fastest mode's factor |1 - 2 mu lambda_max| = 5 => blow-up
        // (possibly to non-finite); detect either.
        let diverged = curve.iter().any(|v| !v.is_finite() || *v > curve[0] * 1e6);
        assert!(diverged, "expected divergence, last={}", curve[1999]);
    }

    #[test]
    fn predicted_matches_simulated_rffklms_on_eq7_data() {
        // End-to-end theory-vs-simulation: run actual RFF-KLMS on Eq. (7)
        // data with the same (Omega, b) and compare the steady state.
        use crate::kaf::{OnlineRegressor, RffKlms};
        use crate::signal::{LinearKernelExpansion, SignalSource};

        let mut rng = run_rng(9, 0);
        // D=512: large enough that the eta' approximation-error term the
        // steady-state formula drops (Prop. 1.2) is actually negligible.
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 512);
        let r = rzz_closed_form(&map, 1.0);
        let mu = 0.8;
        let noise_var = 0.01;

        // average simulated MSE over a few runs
        let runs = 12;
        let horizon = 4000;
        let mut acc = vec![0.0; horizon];
        for run in 0..runs {
            let mut src = LinearKernelExpansion::paper_default(run_rng(10, run), 5, 10);
            let mut f = RffKlms::new(map.clone(), mu);
            let samples = src.take_samples(horizon);
            for (i, s) in samples.iter().enumerate() {
                let e = f.step(&s.x, s.y);
                acc[i] += e * e / runs as f64;
            }
        }
        let sim_ss = acc[horizon - 500..].iter().sum::<f64>() / 500.0;
        let pred_ss = steady_state_mse(&r, mu, noise_var);
        // per-run theta_opt differs; we compare only steady states, which
        // are center-independent. Allow 50% headroom (finite D bias).
        assert!(
            (sim_ss - pred_ss).abs() / pred_ss < 0.5,
            "simulated {sim_ss} vs predicted {pred_ss}"
        );
    }
}
