//! The input-correlation matrix `R_zz` of the RFF features.

use crate::kaf::RffMap;
use crate::linalg::{Cholesky, Mat};
use crate::rng::Rng;

/// Closed-form `R_zz` for `x ~ N(0, σ_x² I_d)` — the paper's §4 formula:
///
/// ```text
/// r_ij = ½ exp(−||ω_i − ω_j||² σ_x²/2) cos(b_i − b_j)
///      + ½ exp(−||ω_i + ω_j||² σ_x²/2) cos(b_i + b_j)
/// ```
///
/// (Derivation: `z_i z_j = (2/D)·cos(ω_iᵀx+b_i)cos(ω_jᵀx+b_j)`, expand
/// with product-to-sum, take the Gaussian characteristic function. The
/// `2/D` normalization of Eq. (3) multiplies the displayed formula.)
pub fn rzz_closed_form(map: &RffMap, sigma_x: f64) -> Mat {
    let d_feat = map.features();
    let sx2 = sigma_x * sigma_x;
    let norm = 2.0 / d_feat as f64; // scale² of Eq. (3)
    let mut r = Mat::zeros(d_feat, d_feat);
    for i in 0..d_feat {
        let wi = map.omega(i);
        let bi = map.phases()[i];
        for j in i..d_feat {
            let wj = map.omega(j);
            let bj = map.phases()[j];
            let mut diff2 = 0.0;
            let mut sum2 = 0.0;
            for k in 0..map.dim() {
                let dm = wi[k] - wj[k];
                let sp = wi[k] + wj[k];
                diff2 += dm * dm;
                sum2 += sp * sp;
            }
            let v = 0.5 * (-diff2 * sx2 / 2.0).exp() * (bi - bj).cos()
                + 0.5 * (-sum2 * sx2 / 2.0).exp() * (bi + bj).cos();
            let v = norm * v;
            r[(i, j)] = v;
            r[(j, i)] = v;
        }
    }
    r
}

/// Monte-Carlo estimate of `R_zz` from `n` Gaussian inputs — validates
/// the closed form and supports non-Gaussian input ablations.
pub fn rzz_empirical(map: &RffMap, sigma_x: f64, n: usize, rng: &mut Rng) -> Mat {
    use crate::rng::{Distribution, Normal};
    let d_feat = map.features();
    let normal = Normal::new(0.0, sigma_x);
    let mut r = Mat::zeros(d_feat, d_feat);
    let mut z = vec![0.0; d_feat];
    let mut x = vec![0.0; map.dim()];
    for _ in 0..n {
        normal.fill(rng, &mut x);
        map.apply_into(&x, &mut z);
        r.rank1_update(1.0 / n as f64, &z, &z);
    }
    r
}

/// Lemma 1 certificate: `R_zz` is strictly positive definite (all ω_i
/// distinct ⇒ PD). Returns the smallest Cholesky pivot-style evidence:
/// `true` iff Cholesky succeeds.
pub fn spd_certificate(rzz: &Mat) -> bool {
    Cholesky::new(rzz).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kaf::kernels::Kernel;
    use crate::rng::run_rng;

    #[test]
    fn closed_form_matches_monte_carlo() {
        let mut rng = run_rng(1, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 3, 16);
        let exact = rzz_closed_form(&map, 1.0);
        let mut rng2 = run_rng(1, 1);
        let emp = rzz_empirical(&map, 1.0, 200_000, &mut rng2);
        let err = crate::linalg::max_abs_diff(&exact, &emp);
        // MC error ~ (2/D)/sqrt(n) per entry; allow generous headroom.
        assert!(err < 5e-3, "closed form vs MC deviates by {err}");
    }

    #[test]
    fn diagonal_entries_formula() {
        // r_ii = (2/D)·(1/2)(1 + exp(-2||ω_i||²σ_x²) cos(2 b_i)).
        let mut rng = run_rng(2, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 2.0 }, 4, 8);
        let r = rzz_closed_form(&map, 1.3);
        for i in 0..8 {
            let w2: f64 = map.omega(i).iter().map(|v| v * v).sum();
            let want = (2.0 / 8.0)
                * 0.5
                * (1.0 + (-2.0 * w2 * 1.3 * 1.3).exp() * (2.0 * map.phases()[i]).cos());
            assert!((r[(i, i)] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn lemma1_spd_holds_for_distinct_frequencies() {
        let mut rng = run_rng(3, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 64);
        let r = rzz_closed_form(&map, 1.0);
        assert!(spd_certificate(&r), "Lemma 1 violated on a random draw");
    }

    #[test]
    fn duplicate_frequencies_break_strict_pd() {
        // Lemma 1's hypothesis is necessary: duplicating (omega, b) makes
        // two identical features and R_zz singular.
        let mut rng = run_rng(4, 0);
        let base = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 2, 4);
        let mut omega_t: Vec<f64> = Vec::new();
        let mut phases: Vec<f64> = Vec::new();
        for i in 0..4 {
            omega_t.extend_from_slice(base.omega(i));
            phases.push(base.phases()[i]);
        }
        // duplicate feature 0
        omega_t.extend_from_slice(base.omega(0));
        phases.push(base.phases()[0]);
        let dup = RffMap::from_parts(omega_t, phases, 2);
        let r = rzz_closed_form(&dup, 1.0);
        assert!(!spd_certificate(&r), "duplicate features must break strict PD");
    }

    #[test]
    fn trace_bounded_by_one() {
        // tr(R_zz) = Σ r_ii <= (2/D)·D·(1/2)(1+1) = 2, and >= 0; typical ~1.
        let mut rng = run_rng(5, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 32);
        let r = rzz_closed_form(&map, 1.0);
        let tr = r.trace();
        assert!(tr > 0.0 && tr <= 2.0, "trace {tr}");
    }
}
