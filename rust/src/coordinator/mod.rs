//! L3 coordinator: the streaming service that owns filter sessions,
//! routes requests, micro-batches PJRT work and orchestrates the paper's
//! Monte-Carlo experiments.
//!
//! Architecture (vLLM-router-shaped, scaled to this paper):
//!
//! ```text
//!  clients ──► SessionHandle ──► BoundedQueue (backpressure)
//!                                   │
//!                             router worker(s)
//!                      ┌───────────┴────────────┐
//!                 train path                predict path
//!              FilterSession             DynamicBatcher: group ≤B
//!            (chunk buffer → PJRT      predicts across sessions →
//!             rffklms/rls chunk,        one rff_predict PJRT call
//!             native remainder)
//! ```
//!
//! The paper's *contribution* lives at the algorithm layer; the
//! coordinator's job is to prove the fixed-size-θ property composes into
//! a real serving system: constant-memory sessions, one executable per
//! (d, D) config shared by every session, no dictionary transfer.

mod orchestrator;
mod service;
mod session;

pub use orchestrator::{McConfig, McResult, Orchestrator};
pub use service::{CoordinatorService, Request, Response, ServiceConfig, ServiceStats};
pub use session::{Algo, Backend, FilterSession, SessionConfig};
