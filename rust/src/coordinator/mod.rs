//! L3 coordinator: the streaming service that owns filter sessions,
//! routes requests, micro-batches PJRT work, spills idle sessions, and
//! orchestrates the paper's Monte-Carlo experiments.
//!
//! Architecture (vLLM-router-shaped, scaled to this paper):
//!
//! ```text
//!  TCP peers ──► daemon (crate::daemon): framed JSON verbs, per-
//!     │          connection in-flight caps, reject-with-diagnostic
//!     │          on overload
//!     │ single-row train/predict        batch & admin verbs
//!     ▼                                       │
//!  Coalescer: cross-connection per-session    │
//!  buffers → TrainBatch / PredictBatch        │
//!  (bitwise = sequential per-row)             │
//!     └───────────────┬───────────────────────┘
//!                     ▼
//!  in-process clients ──► submit / try_submit ──► BoundedQueue
//!                             │                   (backpressure)
//!                       router worker(s)
//!                   (per-class service-time
//!                    histograms → LatencyStats)
//!         ┌───────────────────┼──────────────────────┐
//!    train path          predict path           snapshot path
//!  FilterSession        DynamicBatcher:        SessionSnapshot
//! (chunk buffer →       group ≤B predicts     (versioned JSON;
//!  PJRT chunk, native   across sessions →      map inline or by
//!  remainder) — or a    one rff_predict        MapSpec reference;
//!  DiffusionNetwork     PJRT call; groups      diffusion groups:
//!  group: TrainDiff-    serve consensus-       topology + per-node
//!  usion rounds over    mean θ the same way)   θ in one document)
//!  blocked batch              │                       │
//!  kernels)                   │                       │
//!         └─────┬─────────────┴───────┐         ┌─────┴──────┐
//!               │ SessionStore (sharded,   ◄──► │ SnapshotSink│
//!               │ per-session locks, idle-  spill│ (memory or  │
//!               │ LRU eviction + restore)       │  directory) │
//!               └──────────┬──────────────┘     └────────────┘
//!                          │ Arc<FeatureMap>
//!                   ┌──────┴───────┐  one interned map + f32 view per
//!                   │ MapRegistry  │  (kernel, d, D, seed, kind, param) —
//!                   │ (kaf layer)  │  static RFF / quadrature shared by
//!                   └──────────────┘  sessions AND diffusion groups;
//!                                     adaptive-RFF sessions start on the
//!                                     interned draw and clone-on-first-Ω-
//!                                     update (their snapshots go inline,
//!                                     never by registry reference)
//! ```
//!
//! ## Diffusion groups
//!
//! A whole diffusion network ([`crate::distributed::DiffusionNetwork`])
//! registers as **one session**
//! ([`CoordinatorService::add_diffusion_group`] /
//! [`DiffusionGroupConfig`]): per-node θ over one interned map, trained
//! in whole rounds via [`Request::TrainDiffusion`] (row-major
//! `[rounds · nodes, d]` windows through the blocked batch kernels —
//! bitwise identical to round-by-round stepping), served through the
//! ordinary predict path as the consensus-mean θ, counted under
//! [`ServiceStats`]`::diffusion_rows`, and snapshot/spilled through the
//! same [`SnapshotSink`] machinery as every other session (state type
//! `"diffusion"`, format-versioned, topology by canonical edge list).
//! Nothing in the store or router special-cases groups — a group is a
//! session whose state happens to be a network.
//!
//! The paper's *contribution* lives at the algorithm layer; the
//! coordinator's job is to prove the fixed-size-θ property composes into
//! a real serving system: **constant-memory sessions** (one shared map
//! per config via [`MapRegistry`](crate::kaf::MapRegistry), θ-only
//! per-session state), **bounded residency** (idle sessions spill to a
//! [`SnapshotSink`] and restore transparently on next touch), and one
//! executable per (d, D) config shared by every session — no dictionary
//! transfer anywhere.
//!
//! ## Batch contract
//!
//! The hot path is batch-first end to end. All batch payloads are
//! **row-major `[n, d]`** (`n` concatenated samples), matching the
//! `kaf` layer's [`RffMap`](crate::kaf::RffMap) blocked kernels:
//!
//! * [`Request::TrainBatch`] ships `n` rows in one request — one queue
//!   slot and one response channel round-trip for the whole batch.
//!   [`FilterSession::train_batch`] then runs the filters' blocked batch
//!   kernels (native; bitwise identical to per-row training) or, on the
//!   PJRT backend, dispatches every chunk the rows complete. Stats count
//!   rows, not requests.
//! * Predicts are coalesced by the service itself: the router gathers up
//!   to `max_batch` predict requests (waiting `batch_wait` for a burst),
//!   groups them per session, snapshots a [`PredictState`] and serves the
//!   whole group via one PJRT `rff_predict` execution — or, natively,
//!   one [`PredictState::predict_batch`] call (the Z-free fused kernel)
//!   into a per-worker reused output buffer; zero steady-state
//!   allocations (single-row fallbacks use the same Z-free kernel with
//!   n = 1, also allocation-free).
//! * PJRT sessions buffer partial chunks; `flush()` finishes remainders
//!   through the shared `native_step` f32 kernels — the one place that
//!   math lives. Removing a session flushes its buffered rows first, so
//!   a remove never drops trained samples.
//!
//! ## Session lifecycle: spill and restore
//!
//! With `ServiceConfig { max_resident_sessions, snapshot_dir }` set, the
//! [`SessionStore`] keeps at most `max_resident_sessions` sessions live;
//! beyond that, the least-recently-touched session is **evicted**: its
//! [`SessionSnapshot`] (versioned JSON; every state variant incl.
//! buffered PJRT chunk rows and whole diffusion groups; map by registry
//! reference when interned and frozen — adaptive-RFF sessions always
//! serialize their privately-adapted Ω inline)
//! spills to the configured [`SnapshotSink`] and the live state is
//! dropped. The next touch of that id restores it transparently —
//! snapshot → evict → restore → train is **bitwise identical** to the
//! uninterrupted native run (property-tested in
//! `tests/snapshot_parity.rs`). [`Request::Snapshot`] /
//! [`Request::Restore`] expose the same codec to clients for manual
//! checkpointing and migration; eviction/restore counters land in
//! [`ServiceStats`].
//!
//! ## Sharding and locking contract
//!
//! Sessions live in a [`SessionStore`]: `N` shards (power of two), each a
//! `Mutex<BTreeMap<u64, Resident>>` keyed by a Fibonacci hash of the
//! session id, where a `Resident` is an `Arc<SessionSlot>` — the
//! `Mutex<FilterSession>` plus a lock-free published [`PredictState`]
//! slot (`publish::ArcSlot`) — and an LRU touch stamp. Who holds which
//! lock:
//!
//! * **Shard lock** — held for map operations (insert / remove / lookup /
//!   len) *and* for the restore of a spilled session on touch (decode +
//!   re-insert happen under the shard lock so a racing double-touch
//!   restores exactly once). Never held while training, predicting, or
//!   dispatching device work.
//! * **Session lock** — held for exactly one `train()`/`flush()` call,
//!   which republishes the session's [`PredictState`] into the slot's
//!   lock-free `ArcSlot` before releasing. Trains on different sessions
//!   run truly concurrently across router workers; only same-session
//!   trains serialize. **Predicts take no lock**: the batcher loads the
//!   published state (wait-free; counted in
//!   [`ServiceStats`]`::lockfree_predicts`) and serves batches off it,
//!   so a predict storm can never convoy behind a slow train and vice
//!   versa. What a predict sees is the state as of the last completed
//!   train commit — the same consistency the old snapshot-under-lock
//!   path gave, minus the lock.
//! * **Eviction set** — a store-wide `Mutex<BTreeSet<u64>>` naming
//!   sessions mid-eviction (unlinked from their shard, snapshot not yet
//!   in the sink). Touches of those ids spin briefly until the spill
//!   completes, then restore from the sink; without this, a concurrent
//!   touch would observe the session in *neither* tier and misreport
//!   "no session". Acquired only while a shard lock is held or alone —
//!   lock order is always shard → eviction set → (nothing), and session
//!   locks are never taken under either, so deadlock remains impossible.
//! * **No lock across predict device traffic** — batched PJRT
//!   `rff_predict` executions and native predicts run off the detached
//!   snapshot. (A PJRT-backend *train* chunk does run under its own
//!   session's lock — by design: training mutates θ.) The evictor
//!   serializes its victim the same way `remove` always has: unlink,
//!   wait for in-flight borrowers to drain, then snapshot — so a spilled
//!   snapshot always contains every applied row.

mod native_step;
mod orchestrator;
mod publish;
mod service;
mod session;
mod snapshot;
mod store;

pub use orchestrator::{McConfig, McResult, Orchestrator};
pub use service::{
    CoordinatorService, DropKind, EpochOp, LatencyStats, Request, RequestContext, Response,
    ServiceConfig, ServiceStats, SessionEpochResult, SessionTraffic,
};
pub use session::{
    Algo, Backend, DiffusionGroupConfig, FilterSession, PredictState, SessionConfig,
};
pub use snapshot::{
    DirSink, MemorySink, SessionSnapshot, SnapshotSink, SNAPSHOT_FORMAT, SNAPSHOT_READ_FORMATS,
};
pub use store::{SessionStore, SpillConfig, SpillStats};
