//! L3 coordinator: the streaming service that owns filter sessions,
//! routes requests, micro-batches PJRT work and orchestrates the paper's
//! Monte-Carlo experiments.
//!
//! Architecture (vLLM-router-shaped, scaled to this paper):
//!
//! ```text
//!  clients ──► SessionHandle ──► BoundedQueue (backpressure)
//!                                   │
//!                             router worker(s)
//!                      ┌───────────┴────────────┐
//!                 train path                predict path
//!              FilterSession             DynamicBatcher: group ≤B
//!            (chunk buffer → PJRT      predicts across sessions →
//!             rffklms/rls chunk,        one rff_predict PJRT call
//!             native remainder)
//! ```
//!
//! The paper's *contribution* lives at the algorithm layer; the
//! coordinator's job is to prove the fixed-size-θ property composes into
//! a real serving system: constant-memory sessions, one executable per
//! (d, D) config shared by every session, no dictionary transfer.
//!
//! ## Batch contract
//!
//! The hot path is batch-first end to end. All batch payloads are
//! **row-major `[n, d]`** (`n` concatenated samples), matching the
//! `kaf` layer's [`RffMap`](crate::kaf::RffMap) blocked kernels:
//!
//! * [`Request::TrainBatch`] ships `n` rows in one request — one queue
//!   slot and one response channel round-trip for the whole batch.
//!   [`FilterSession::train_batch`] then runs the filters' blocked batch
//!   kernels (native; bitwise identical to per-row training) or, on the
//!   PJRT backend, dispatches every chunk the rows complete (one request
//!   → possibly several chunk dispatches). Stats count rows, not
//!   requests.
//! * Predicts are coalesced by the service itself: the router gathers up
//!   to `max_batch` predict requests (waiting `batch_wait` for a burst),
//!   groups them per session, snapshots a [`PredictState`] and serves the
//!   whole group via one PJRT `rff_predict` execution — or, natively,
//!   one [`PredictState::predict_batch`] call (the Z-free fused kernel)
//!   into a per-worker reused output buffer; zero steady-state
//!   allocations.
//! * PJRT sessions buffer partial chunks; `flush()` finishes remainders
//!   through the shared `native_step` f32 kernels — the one place that
//!   math lives.
//!
//! ## Sharding and locking contract
//!
//! Sessions live in a [`SessionStore`]: `N` shards (power of two), each a
//! `Mutex<BTreeMap<u64, Arc<Mutex<FilterSession>>>>` keyed by a Fibonacci
//! hash of the session id. Who holds which lock:
//!
//! * **Shard lock** — held only by `add_session` / `remove_session` /
//!   `session_count` and by the id→cell lookup inside train/flush/predict
//!   routing. Released before any filter math runs.
//! * **Session lock** — held for exactly one `train()`/`flush()` call, or
//!   just long enough for the predict batcher to snapshot `(θ, Ω, b)`
//!   into a [`PredictState`]. Trains on different sessions therefore run
//!   truly concurrently across router workers; only same-session trains
//!   serialize.
//! * **No lock across predict device traffic** — batched PJRT
//!   `rff_predict` executions and native per-row predicts both run off
//!   the detached snapshot, so a slow predict batch never blocks
//!   training, and a training burst never blocks serving other sessions.
//!   (A PJRT-backend *train* chunk does run under its own session's
//!   lock — by design: training mutates θ — which serializes work on
//!   that one session only.)
//! * Lock order is always shard → session, one of each at most, so the
//!   coordinator cannot deadlock.

mod native_step;
mod orchestrator;
mod service;
mod session;
mod store;

pub use orchestrator::{McConfig, McResult, Orchestrator};
pub use service::{CoordinatorService, Request, Response, ServiceConfig, ServiceStats};
pub use session::{Algo, Backend, FilterSession, PredictState, SessionConfig};
pub use store::SessionStore;
