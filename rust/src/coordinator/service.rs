//! The coordinator service: request router + worker pool + dynamic
//! predict batcher over bounded (backpressure) queues.
//!
//! Requests enter through [`CoordinatorService::submit`]; router workers
//! drain the queue, dispatch training samples to their sessions and
//! micro-batch prediction requests per (d, D) config into single PJRT
//! `rff_predict` executions (padding the fixed batch with zero rows).
//!
//! ## Concurrency
//!
//! Sessions live in a sharded [`SessionStore`]: trains on *different*
//! sessions run truly concurrently across router workers (only same-
//! session trains serialize, on that session's own mutex), and the
//! predict path is **lock-free**: every train/flush/restore commit
//! republishes the session's `(θ, Ω, b)` as a
//! [`PredictState`](super::session::PredictState) into the session
//! slot's wait-free publication cell
//! ([`ArcSlot`](super::publish::ArcSlot)), and [`dispatch_predicts`]
//! loads that published state without ever touching the session mutex
//! (counted in [`ServiceStats::lockfree_predicts`]). A predict serves
//! the state as of the last *completed* commit — exactly what the old
//! snapshot-under-lock path served, minus the lock, so a predict storm
//! never convoys behind a slow train and vice versa. (A PJRT-backend
//! train does hold its own session's lock across the chunk dispatch,
//! serializing only that session.) See [`SessionStore`] for the full
//! locking contract.
//!
//! ## Epoch scheduling
//!
//! [`CoordinatorService::run_epoch`] bypasses the queue for offline /
//! replay workloads: it takes one epoch of per-session traffic
//! ([`SessionTraffic`]) and shards it across a work-stealing scheduler
//! ([`crate::exec::run_stealing`]) with **sessions as the parallel
//! unit** — each session's ops run sequentially in submission order on
//! whichever worker claims them, so per-session trajectories are
//! bitwise identical at any worker count while distinct sessions
//! saturate every core.
//!
//! ## Stats semantics
//!
//! `trained` / `predicted` count *successful* operations only; failed
//! requests (unknown session, dim mismatch, dead executor) count under
//! `errors` instead, and the two never double-count one request.
//! `trained` counts **rows**, not requests: a [`Request::TrainBatch`] of
//! `n` rows moves it by `n`, identically to `n` single trains. `trained`
//! means *accepted* — on the PJRT backend a row may still be buffered in
//! a partial chunk when it is counted. On a PJRT chunk-dispatch failure
//! mid-batch the request reports an error and counts no rows toward
//! `trained`, even though chunks dispatched earlier in the same request
//! remain applied; blind retries of a failed `TrainBatch` therefore
//! re-train those rows. The per-session `samples_seen` is the row-exact
//! applied-rows ground truth.
//!
//! ## Residency
//!
//! With `max_resident_sessions > 0` the store spills idle-LRU sessions
//! to a snapshot sink ([`DirSink`] under `snapshot_dir`, else
//! [`MemorySink`]) and restores them transparently on the next touch —
//! requests never observe eviction except as latency. `stats().spill`
//! carries the eviction/restore counters; [`Request::Snapshot`] /
//! [`Request::Restore`] expose the same snapshot codec for manual
//! checkpointing, rollback and migration.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::exec::{run_stealing, BoundedQueue};
use crate::kaf::MapRegistry;
use crate::metrics::LogHistogram;
use crate::runtime::ExecutorHandle;

use super::session::{DiffusionGroupConfig, FilterSession, SessionConfig};
use super::snapshot::{DirSink, MemorySink, SessionSnapshot, SnapshotSink};
use super::store::{SessionStore, SpillConfig, SpillStats};

/// Service tuning knobs.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Router worker threads.
    pub workers: usize,
    /// Request queue capacity (backpressure bound).
    pub queue_capacity: usize,
    /// Max predicts to fuse into one PJRT batch.
    pub max_batch: usize,
    /// Gather window: after the first request of a batch arrives, how
    /// long to wait for more before dispatching. `ZERO` (the default)
    /// batches opportunistically — whatever is already queued — adding
    /// no latency to synchronous request loops; bursty predict clients
    /// set a small window (e.g. 1–2 ms) to trade tail latency for fused
    /// PJRT dispatches.
    pub batch_wait: Duration,
    /// How long an **idle** router worker blocks waiting for the *first*
    /// request of a batch before re-checking queue state (was a hardcoded
    /// 50 ms). This is purely a parking cadence: it bounds how often idle
    /// workers wake, costs nothing in request latency (a push wakes a
    /// parked worker immediately via the queue's condvar) and only
    /// matters for how promptly workers notice `shutdown()`. Lower it in
    /// latency-sensitive tests; raising it saves idle wakeups.
    pub first_wait: Duration,
    /// Session-store shards (rounded up to a power of two). More shards
    /// mean less contention on add/remove/lookup under many sessions;
    /// per-session train/predict serialization is unaffected by this
    /// knob — that always uses the session's own lock.
    pub shards: usize,
    /// Resident-session cap: beyond this many live sessions, the store
    /// evicts the least-recently-touched one into a snapshot sink and
    /// restores it transparently on its next touch. `0` (the default)
    /// disables eviction — every session stays resident forever, the
    /// pre-spill behavior.
    pub max_resident_sessions: usize,
    /// Where evicted sessions spill when a cap is set: a directory
    /// (one JSON snapshot file per session, crash-tolerant writes) or,
    /// when `None`, an in-memory sink (sessions demote to their
    /// serialized form but stay in RAM).
    pub snapshot_dir: Option<PathBuf>,
    /// Fault injection (chaos harness only): stall every router worker
    /// for this long after it pops a batch, simulating a slow router so
    /// deadline expiry and queue saturation become reachable under
    /// loopback latencies. Compiled out of release builds.
    #[cfg(any(test, feature = "fault-injection"))]
    pub fault_stall: Option<Duration>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 1024,
            max_batch: 32,
            batch_wait: Duration::ZERO,
            first_wait: Duration::from_millis(50),
            shards: 16,
            max_resident_sessions: 0,
            snapshot_dir: None,
            #[cfg(any(test, feature = "fault-injection"))]
            fault_stall: None,
        }
    }
}

/// Per-request deadline/cancellation context, threaded from the wire
/// layer down to the router worker that finally serves (or sheds) the
/// request — the cancellation-as-boundary-concern design: every
/// *boundary* (connection dispatch, coalescer buffer, queue admission,
/// router dequeue, response demux) checks the context; the compute
/// kernels themselves never do. Consequences:
///
/// * **Queued** work that is cancelled or expires is dropped before it
///   runs (diagnostic reply for cancels, counted suppressed drop for
///   deadline expiry).
/// * **In-flight** work runs to completion — cancellation is
///   best-effort, it never corrupts a session mid-train — but its reply
///   is suppressed and counted ([`Response::Dropped`]).
///
/// The default context (no deadline, no cancel flag) makes every check
/// free-ish and is what all non-wire callers use, so deadline-disabled
/// traffic is byte-identical to the pre-context behavior.
#[derive(Clone, Debug, Default)]
pub struct RequestContext {
    /// Absolute expiry instant (wire `deadline_ms` is relative — the
    /// daemon converts at parse time; clocks are never compared across
    /// hosts). `None` = no deadline.
    pub deadline: Option<Instant>,
    /// Cooperative cancel flag, shared with the connection's cancel
    /// registry: a `cancel` verb naming this request's id sets it.
    pub cancelled: Option<Arc<AtomicBool>>,
    /// The client-chosen request id (wire `id`), carried for
    /// diagnostics; 0 for non-wire callers.
    pub correlation_id: u64,
}

impl RequestContext {
    /// Deadline passed?
    pub fn is_expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Cancel flag raised?
    pub fn is_cancelled(&self) -> bool {
        self.cancelled.as_ref().is_some_and(|c| c.load(Ordering::Relaxed))
    }

    /// Cancelled or expired — the request's reply no longer matters to
    /// its sender.
    pub fn is_dead(&self) -> bool {
        self.is_cancelled() || self.is_expired()
    }
}

/// Why a reply was deliberately suppressed (see [`Response::Dropped`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropKind {
    /// The request's deadline passed before its reply could matter.
    Deadline,
    /// The request was cancelled while in flight.
    Cancelled,
}

/// A request to the coordinator.
pub enum Request {
    /// Train session `session` on one labelled sample.
    Train {
        /// Target session id.
        session: u64,
        /// Input vector.
        x: Vec<f64>,
        /// Target.
        y: f64,
        /// Where to send the resulting a-priori errors (may be empty
        /// while a PJRT chunk fills).
        resp: Sender<Response>,
        /// Deadline/cancellation context (default = none).
        ctx: RequestContext,
    },
    /// Train session `session` on `n` rows in one request — amortizes
    /// queue/channel overhead over the whole batch and lets the session
    /// run its blocked batch kernels (native) or fill whole PJRT chunks
    /// in one submit. One response carries every error that became
    /// available; stats count the rows, not the request.
    TrainBatch {
        /// Target session id.
        session: u64,
        /// Row-major `[n, dim]` inputs.
        xs: Vec<f64>,
        /// The `n` targets.
        ys: Vec<f64>,
        /// Where to send the resulting a-priori errors.
        resp: Sender<Response>,
        /// Deadline/cancellation context (default = none).
        ctx: RequestContext,
    },
    /// Train diffusion group `group` on a window of whole rounds: `xs`
    /// is row-major `[rounds · nodes, dim]` in round-major order (round
    /// `r`'s node `k` is row `r·nodes + k`), `ys` the matching targets.
    /// The group runs its blocked batch kernels over the whole window
    /// (bitwise identical to round-by-round stepping); one response
    /// carries every per-node a-priori error in row order. Stats count
    /// the rows under `diffusion_rows`.
    TrainDiffusion {
        /// Target group (a session id registered via
        /// [`CoordinatorService::add_diffusion_group`]).
        group: u64,
        /// Row-major `[rounds · nodes, dim]` inputs.
        xs: Vec<f64>,
        /// One target per row.
        ys: Vec<f64>,
        /// Where to send the per-node a-priori errors.
        resp: Sender<Response>,
        /// Deadline/cancellation context (default = none).
        ctx: RequestContext,
    },
    /// Predict with session `session`'s current model.
    Predict {
        /// Target session id.
        session: u64,
        /// Input vector.
        x: Vec<f64>,
        /// Response channel.
        resp: Sender<Response>,
        /// Deadline/cancellation context (default = none).
        ctx: RequestContext,
    },
    /// Predict `n` rows against one session in a single request —
    /// the pre-batched dual of [`Request::TrainBatch`], and what the
    /// wire daemon's coalescer emits after merging single-row predict
    /// traffic from many connections. Served off the lock-free
    /// published [`PredictState`](super::session::PredictState) via one
    /// blocked `predict_batch` kernel call; one
    /// [`Response::Predictions`] carries all `n` values in row order.
    /// Stats count the rows, not the request.
    PredictBatch {
        /// Target session id.
        session: u64,
        /// Row-major `[n, dim]` probes.
        xs: Vec<f64>,
        /// Response channel (receives [`Response::Predictions`]).
        resp: Sender<Response>,
        /// Deadline/cancellation context (default = none).
        ctx: RequestContext,
    },
    /// Flush any buffered partial chunk of `session`.
    Flush {
        /// Target session id.
        session: u64,
        /// Response channel.
        resp: Sender<Response>,
    },
    /// Serialize session `session`'s complete state to a versioned
    /// [`SessionSnapshot`] document (buffered PJRT chunk rows included —
    /// no flush happens). The same codec the store's eviction path uses.
    Snapshot {
        /// Target session id.
        session: u64,
        /// Response channel (receives [`Response::Snapshot`]).
        resp: Sender<Response>,
    },
    /// Install the session serialized in `snapshot` under id `session`
    /// (replacing any current occupant — checkpoint rollback and
    /// migration both want exactly that). Reference-mode maps resolve
    /// through the service's registry, so restored fleets keep sharing
    /// one `(Ω, b)`.
    Restore {
        /// Session id to install under.
        session: u64,
        /// A document produced by [`Request::Snapshot`] (or
        /// [`SessionSnapshot::to_json`]).
        snapshot: String,
        /// Response channel (receives [`Response::Restored`]).
        resp: Sender<Response>,
    },
}

impl Request {
    /// The deadline/cancellation context, for the work-carrying
    /// variants. Admin requests (`Flush`/`Snapshot`/`Restore`) carry
    /// none — they are cheap, rare, and always answered.
    fn context(&self) -> Option<&RequestContext> {
        match self {
            Request::Train { ctx, .. }
            | Request::TrainBatch { ctx, .. }
            | Request::TrainDiffusion { ctx, .. }
            | Request::Predict { ctx, .. }
            | Request::PredictBatch { ctx, .. } => Some(ctx),
            Request::Flush { .. } | Request::Snapshot { .. } | Request::Restore { .. } => None,
        }
    }

    /// Cancelled or expired while still queued — sheddable.
    fn is_dead(&self) -> bool {
        self.context().is_some_and(RequestContext::is_dead)
    }
}

/// A response from the coordinator.
#[derive(Clone, Debug)]
pub enum Response {
    /// Errors emitted by a train/flush (empty while buffering).
    Trained(Vec<f64>),
    /// A prediction.
    Predicted(f64),
    /// Predictions from a [`Request::PredictBatch`], in row order.
    Predictions(Vec<f64>),
    /// A serialized session snapshot.
    Snapshot(String),
    /// A snapshot was installed.
    Restored,
    /// Request failed.
    Error(String),
    /// The reply was deliberately suppressed: the request's deadline
    /// passed or it was cancelled after admission. The daemon's writer
    /// recognizes this and writes **no frame** (counted under
    /// `DaemonStats::suppressed_replies`); sync callers treat it as an
    /// error. Exactly one of {real reply, [`Response::Error`],
    /// `Dropped`} resolves every admitted request — the conservation
    /// law the chaos suite asserts.
    Dropped(DropKind),
}

/// One session's share of an epoch: its ops, executed **sequentially in
/// this order** by whichever scheduler worker claims the session (see
/// [`CoordinatorService::run_epoch`]).
pub struct SessionTraffic {
    /// Target session id.
    pub session: u64,
    /// The session's traffic, in submission order.
    pub ops: Vec<EpochOp>,
}

/// One operation inside a [`SessionTraffic`].
pub enum EpochOp {
    /// Train on row-major `[n, dim]` inputs with `n` targets — the same
    /// blocked batch kernels [`Request::TrainBatch`] runs.
    TrainBatch {
        /// Row-major `[n, dim]` inputs.
        xs: Vec<f64>,
        /// The `n` targets.
        ys: Vec<f64>,
    },
    /// Predict over row-major `[n, dim]` probes, served off the
    /// lock-free published [`PredictState`](super::session::PredictState)
    /// — i.e. the state as of this session's last committed train op.
    PredictBatch {
        /// Row-major `[n, dim]` probes.
        xs: Vec<f64>,
    },
}

/// What one session's epoch produced, in op submission order.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionEpochResult {
    /// The session this result belongs to.
    pub session: u64,
    /// A-priori errors from every `TrainBatch`, concatenated.
    pub errors: Vec<f64>,
    /// Predictions from every `PredictBatch`, concatenated.
    pub predictions: Vec<f64>,
    /// First failure, if any — the session's remaining ops are skipped
    /// (an epoch replay with a failed op is not a trajectory worth
    /// continuing; other sessions are unaffected).
    pub failed: Option<String>,
}

/// Counters exported by the service.
#[derive(Debug, Default)]
pub struct ServiceStats {
    /// Training **rows** *accepted* successfully (failed requests count
    /// under `errors`, never here). Rows, not requests: a `TrainBatch`
    /// of `n` rows adds `n`, the same as `n` single `Train`s. On the
    /// PJRT backend acceptance precedes application — a row counts when
    /// its request succeeds, which may be while it is still buffered in a
    /// partial chunk; the per-session `samples_seen` counts *applied*
    /// rows and is the row-exact ground truth.
    pub trained: AtomicU64,
    /// Diffusion rows applied successfully through
    /// [`Request::TrainDiffusion`] (`rounds × nodes` per request —
    /// node-rows, the same unit the per-group `samples_seen` counts).
    /// Kept separate from `trained` so filter-session and group traffic
    /// stay individually observable.
    pub diffusion_rows: AtomicU64,
    /// Predictions served successfully (failures count under `errors`).
    pub predicted: AtomicU64,
    /// Prediction **rows** served without touching any session mutex —
    /// off the lock-free published [`PredictState`]
    /// (see [`super::publish::ArcSlot`]). Every batched/epoch predict
    /// now takes this path, so in steady state this tracks `predicted`;
    /// it is kept separate so the lock-free property itself is
    /// observable (a regression re-introducing a lock shows up as this
    /// counter falling behind).
    pub lockfree_predicts: AtomicU64,
    /// PJRT predict batches dispatched.
    pub predict_batches: AtomicU64,
    /// Total rows in dispatched predict batches (fill ratio = rows /
    /// (batches * B)).
    pub predict_rows: AtomicU64,
    /// Requests that returned an error.
    pub errors: AtomicU64,
    /// Responses that could not be delivered because the requester's
    /// receiver was already gone (client disconnected mid-request, or a
    /// sync caller timed out and dropped its channel). The operation
    /// itself still ran and is counted under its own counter; this one
    /// makes disconnect storms observable instead of silently eating
    /// the send error.
    pub dropped_responses: AtomicU64,
    /// Requests rejected **before dispatch** because their `deadline_ms`
    /// had already expired on arrival (the daemon replies with a
    /// diagnostic error; nothing was queued). Counts requests.
    pub deadline_rejects: AtomicU64,
    /// Requests shed **after admission** because their deadline expired
    /// while queued, coalesced, or in flight — the reply is suppressed
    /// ([`Response::Dropped`]), never delivered late. Counts requests,
    /// one per suppressed reply.
    pub deadline_drops: AtomicU64,
    /// Requests resolved by a `cancel` verb: still-queued work gets a
    /// diagnostic error reply, in-flight work completes but its reply is
    /// suppressed. Counts requests, one per cancel-induced resolution
    /// (a cancel that arrives after the reply resolved counts nothing).
    pub cancelled: AtomicU64,
    /// Sessions whose mutex was found poisoned (a holder panicked
    /// mid-operation) and recovered via `PoisonError::into_inner` — the
    /// session stays servable; θ reflects every *completed* row. Counts
    /// incidents, not subsequent locks (the poison flag is cleared).
    pub poisoned_recoveries: AtomicU64,
    /// Explicit [`Request::Snapshot`]s served successfully.
    pub snapshots: AtomicU64,
    /// Explicit [`Request::Restore`]s served successfully.
    pub restored: AtomicU64,
    /// Eviction/restore bookkeeping, shared with the session store (the
    /// store increments these as it spills and re-admits sessions).
    pub spill: Arc<SpillStats>,
    /// Per-request-class service-time histograms recorded at the router
    /// (p50/p95/p99 via [`LogHistogram::quantile`]; the daemon's `stats`
    /// verb exports them over the wire).
    pub latency: LatencyStats,
}

/// Router-side service-time histograms, one per request class, in
/// **seconds** (a [`LogHistogram`] spans 1 ns – 1000 s at ~2% bucket
/// resolution). "Service time" is arm execution time at the router
/// worker — from the moment a worker starts the request to the moment
/// its response is sent — *not* end-to-end latency: queue wait and wire
/// time are excluded, which is exactly what makes the histograms useful
/// for telling "the router is slow" apart from "the queue is deep".
///
/// Batched requests record once **per row** ([`LogHistogram::record_n`])
/// so quantiles stay row-weighted and comparable between batched and
/// single-row traffic.
#[derive(Default)]
pub struct LatencyStats {
    /// Train-class requests: `Train`, `TrainBatch`, `TrainDiffusion`,
    /// `Flush`.
    pub train: Mutex<LogHistogram>,
    /// Predict-class requests: `Predict` (recorded per gathered group)
    /// and `PredictBatch`.
    pub predict: Mutex<LogHistogram>,
    /// [`Request::Snapshot`] serialization time.
    pub snapshot: Mutex<LogHistogram>,
    /// [`Request::Restore`] decode + install time.
    pub restore: Mutex<LogHistogram>,
}

impl LatencyStats {
    /// The classes in a stable export order, with their wire names.
    pub fn classes(&self) -> [(&'static str, &Mutex<LogHistogram>); 4] {
        [
            ("train", &self.train),
            ("predict", &self.predict),
            ("snapshot", &self.snapshot),
            ("restore", &self.restore),
        ]
    }
}

impl std::fmt::Debug for LatencyStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut d = f.debug_struct("LatencyStats");
        for (name, hist) in self.classes() {
            let h = hist.lock().unwrap_or_else(PoisonError::into_inner);
            d.field(name, &format_args!("{}", h.report_ms(name)));
        }
        d.finish()
    }
}

/// Record one observation of `dt` into a latency histogram.
fn observe(hist: &Mutex<LogHistogram>, dt: Duration) {
    observe_n(hist, dt, 1);
}

/// Record `rows` row-observations of the same service time `dt`.
fn observe_n(hist: &Mutex<LogHistogram>, dt: Duration, rows: u64) {
    // clamp to the histogram's 1 ns floor so a sub-tick measurement
    // still lands in the bottom bucket instead of the zero clamp
    let secs = dt.as_secs_f64().max(1e-9);
    hist.lock().unwrap_or_else(PoisonError::into_inner).record_n(secs, rows);
}

/// The running coordinator service.
pub struct CoordinatorService {
    queue: Arc<BoundedQueue<Request>>,
    sessions: Arc<SessionStore>,
    stats: Arc<ServiceStats>,
    registry: Arc<MapRegistry>,
    executor: Option<ExecutorHandle>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Shared with router workers: a [`Request::Restore`] under an
    /// explicit id must advance this past that id, or a later
    /// `add_session` could allocate the same id and silently clobber the
    /// restored session.
    next_id: Arc<AtomicU64>,
}

impl CoordinatorService {
    /// Start the service with `executor` (None disables PJRT batching —
    /// predicts then run natively).
    pub fn start(config: ServiceConfig, executor: Option<ExecutorHandle>) -> Self {
        let queue = Arc::new(BoundedQueue::new(config.queue_capacity));
        let stats = Arc::new(ServiceStats::default());
        let registry = Arc::new(MapRegistry::new());
        let sessions = if config.max_resident_sessions > 0 {
            let sink: Arc<dyn SnapshotSink> = match &config.snapshot_dir {
                Some(dir) => Arc::new(DirSink::new(dir)),
                None => Arc::new(MemorySink::new()),
            };
            Arc::new(SessionStore::with_spill(
                config.shards,
                SpillConfig {
                    max_resident: config.max_resident_sessions,
                    sink,
                    registry: Arc::clone(&registry),
                    executor: executor.clone(),
                    stats: Arc::clone(&stats.spill),
                },
            ))
        } else {
            Arc::new(SessionStore::new(config.shards))
        };
        let next_id = Arc::new(AtomicU64::new(1));
        let workers = (0..config.workers.max(1))
            .map(|i| {
                let queue = Arc::clone(&queue);
                let sessions = Arc::clone(&sessions);
                let stats = Arc::clone(&stats);
                let registry = Arc::clone(&registry);
                let next_id = Arc::clone(&next_id);
                let executor = executor.clone();
                let cfg = config.clone();
                std::thread::Builder::new()
                    .name(format!("rff-kaf-router-{i}"))
                    .spawn(move || {
                        router_loop(queue, sessions, stats, registry, next_id, executor, cfg)
                    })
                    .expect("spawning router worker")
            })
            .collect();
        Self { queue, sessions, stats, registry, executor, workers, next_id }
    }

    /// Register a session, returning its id. Touches one shard only (may
    /// evict the LRU session when a resident cap is configured).
    pub fn add_session(&self, session: FilterSession) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.sessions.insert(id, session);
        id
    }

    /// Register a session whose map is **interned** in the service's
    /// [`MapRegistry`]: every session added with the same
    /// `(config.kernel, dim, features, seed)` shares one resident
    /// `(Ω, b)`, and its eviction snapshots store the map as a reference
    /// instead of by value.
    pub fn add_session_from_spec(&self, config: SessionConfig, seed: u64) -> Result<u64> {
        let session =
            FilterSession::from_spec(config, seed, &self.registry, self.executor.clone())?;
        Ok(self.add_session(session))
    }

    /// Register a **diffusion group** as a session: the whole network —
    /// per-node θ over one interned map — lives under one id in the
    /// sharded store, trains via [`Request::TrainDiffusion`], serves
    /// consensus-mean predictions through the ordinary predict path, and
    /// snapshots/spills through the same machinery as every other
    /// session. The map is interned by
    /// `(config.session.kernel, dim, features, seed)` — a group and a
    /// fleet of plain sessions with the same spec share one `(Ω, b)`.
    pub fn add_diffusion_group(
        &self,
        config: DiffusionGroupConfig,
        seed: u64,
    ) -> Result<u64> {
        let session = FilterSession::diffusion_from_spec(config, seed, &self.registry)?;
        Ok(self.add_session(session))
    }

    /// Remove a session, returning it with any buffered partial PJRT
    /// chunk rows **flushed** through the native kernels first — a
    /// remove never silently drops trained samples (it used to drop up
    /// to `chunk_n − 1` of them). Waits out any in-flight request on the
    /// session; restores the session from the spill sink if it was
    /// evicted.
    pub fn remove_session(&self, id: u64) -> Option<FilterSession> {
        let mut session = self.sessions.remove(id)?;
        // flush() on a native session is a no-op; on a PJRT session it
        // runs the remainder through native_step (pure computation, no
        // dispatch) and cannot fail
        let _ = session.flush();
        Some(session)
    }

    /// The service's feature-map registry (interned `(Ω, b)` draws).
    pub fn registry(&self) -> &Arc<MapRegistry> {
        &self.registry
    }

    /// Number of live sessions.
    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// The shared session store (shard layout introspection).
    pub fn store(&self) -> &SessionStore {
        &self.sessions
    }

    /// Submit a request (blocks when the queue is full — backpressure).
    pub fn submit(&self, req: Request) -> Result<()> {
        self.queue
            .push(req)
            .map_err(|_| anyhow::anyhow!("service shut down"))
    }

    /// Non-blocking submit: `Ok(true)` = accepted, `Ok(false)` = the
    /// queue is at capacity *right now*. Callers that must never park on
    /// a full queue (the wire daemon's direct dispatch path) use this to
    /// reject with a diagnostic instead of buffering unboundedly or
    /// stalling a connection's reader. `Err` only after shutdown.
    ///
    /// Saturation degrades expired-first: when the queue is full, any
    /// queued request whose context is already dead (deadline passed or
    /// cancelled) is shed — resolved with its counted drop/diagnostic —
    /// before live work is rejected, so a deadline storm cannot starve
    /// requests that still matter.
    pub fn try_submit(&self, req: Request) -> Result<bool> {
        let req = match self.queue.try_push_or_return(req) {
            Ok(None) => return Ok(true),
            Ok(Some(r)) => r,
            Err(_) => anyhow::bail!("service shut down"),
        };
        let shed = self.queue.shed(Request::is_dead);
        if shed.is_empty() {
            return Ok(false);
        }
        for dead in shed {
            resolve_shed(&self.stats, dead);
        }
        match self.queue.try_push_or_return(req) {
            Ok(None) => Ok(true),
            Ok(Some(_)) => Ok(false),
            Err(_) => anyhow::bail!("service shut down"),
        }
    }

    /// The request queue's capacity (for overload diagnostics).
    pub fn queue_capacity(&self) -> usize {
        self.queue.capacity()
    }

    /// Service statistics.
    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }

    /// Drain the queue and stop the workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Convenience synchronous wrappers (used by tests/examples) -------

    /// Train and wait for the response.
    pub fn train_sync(&self, session: u64, x: Vec<f64>, y: f64) -> Result<Vec<f64>> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Request::Train { session, x, y, resp: tx, ctx: RequestContext::default() })?;
        match rx.recv()? {
            Response::Trained(e) => Ok(e),
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Train on a whole batch of rows (`xs` row-major `[n, dim]`) and
    /// wait for the response.
    pub fn train_batch_sync(&self, session: u64, xs: Vec<f64>, ys: Vec<f64>) -> Result<Vec<f64>> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Request::TrainBatch {
            session,
            xs,
            ys,
            resp: tx,
            ctx: RequestContext::default(),
        })?;
        match rx.recv()? {
            Response::Trained(e) => Ok(e),
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Train a diffusion group on a window of whole rounds and wait for
    /// the per-node errors.
    pub fn train_diffusion_sync(
        &self,
        group: u64,
        xs: Vec<f64>,
        ys: Vec<f64>,
    ) -> Result<Vec<f64>> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Request::TrainDiffusion {
            group,
            xs,
            ys,
            resp: tx,
            ctx: RequestContext::default(),
        })?;
        match rx.recv()? {
            Response::Trained(e) => Ok(e),
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Predict and wait for the response.
    pub fn predict_sync(&self, session: u64, x: Vec<f64>) -> Result<f64> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Request::Predict { session, x, resp: tx, ctx: RequestContext::default() })?;
        match rx.recv()? {
            Response::Predicted(v) => Ok(v),
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Predict a whole row-major `[n, dim]` batch of probes against one
    /// session and wait for the `n` predictions.
    pub fn predict_batch_sync(&self, session: u64, xs: Vec<f64>) -> Result<Vec<f64>> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Request::PredictBatch {
            session,
            xs,
            resp: tx,
            ctx: RequestContext::default(),
        })?;
        match rx.recv()? {
            Response::Predictions(v) => Ok(v),
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Flush and wait.
    pub fn flush_sync(&self, session: u64) -> Result<Vec<f64>> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Request::Flush { session, resp: tx })?;
        match rx.recv()? {
            Response::Trained(e) => Ok(e),
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Snapshot a session's state and wait for the serialized document.
    pub fn snapshot_sync(&self, session: u64) -> Result<String> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Request::Snapshot { session, resp: tx })?;
        match rx.recv()? {
            Response::Snapshot(text) => Ok(text),
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }

    /// Run one epoch of per-session traffic across `workers` threads via
    /// the work-stealing scheduler ([`crate::exec::run_stealing`]),
    /// bypassing the request queue — the offline/replay fast path.
    ///
    /// **Sessions are the parallel unit**: each [`SessionTraffic`] is one
    /// schedulable task, its ops executed sequentially in submission
    /// order, trains under the session lock (republishing the predict
    /// state at every commit) and predicts off the lock-free published
    /// state. Consequences:
    ///
    /// * Per-session trajectories (errors, predictions, `samples_seen`)
    ///   are **bitwise identical at any worker count** — only the
    ///   interleaving *across* sessions varies, and no result depends on
    ///   it. (Asserted per tier/worker-count in
    ///   `tests/epoch_determinism.rs`.)
    /// * Throughput scales with the number of concurrently-trainable
    ///   sessions; stealing rebalances heterogeneous sessions (a KRLS
    ///   session costs ~D× a KLMS one per row) without any static
    ///   partitioning. `BENCH_scaling.json` (`benches/scaling.rs`)
    ///   records the rows/s × workers curve.
    ///
    /// Results come back in input order. Stats are updated exactly as the
    /// queued paths would: `trained` by rows accepted, `predicted` /
    /// `lockfree_predicts` by rows served, failures under `errors`.
    pub fn run_epoch(
        &self,
        traffic: Vec<SessionTraffic>,
        workers: usize,
    ) -> Vec<SessionEpochResult> {
        let sessions = &self.sessions;
        let stats = &self.stats;
        run_stealing(traffic, workers, |_, t| {
            let mut res = SessionEpochResult {
                session: t.session,
                errors: Vec::new(),
                predictions: Vec::new(),
                failed: None,
            };
            let Some(cell) = sessions.get(t.session) else {
                stats.errors.fetch_add(1, Ordering::Relaxed);
                res.failed = Some(format!("no session {}", t.session));
                return res;
            };
            for op in t.ops {
                match op {
                    EpochOp::TrainBatch { xs, ys } => {
                        let rows = ys.len() as u64;
                        let mut s = lock_counted(&cell, stats);
                        match s.train_batch(&xs, &ys) {
                            Ok(mut errs) => {
                                cell.republish(&s);
                                drop(s);
                                stats.trained.fetch_add(rows, Ordering::Relaxed);
                                res.errors.append(&mut errs);
                            }
                            Err(e) => {
                                drop(s);
                                stats.errors.fetch_add(1, Ordering::Relaxed);
                                res.failed = Some(e.to_string());
                                break;
                            }
                        }
                    }
                    EpochOp::PredictBatch { xs } => {
                        let snap = cell.predict_handle();
                        let dim = snap.dim();
                        if xs.len() % dim != 0 {
                            stats.errors.fetch_add(1, Ordering::Relaxed);
                            res.failed = Some(format!(
                                "predict probes ({} values) not a multiple of dim {dim}",
                                xs.len()
                            ));
                            break;
                        }
                        let n = xs.len() / dim;
                        let start = res.predictions.len();
                        res.predictions.resize(start + n, 0.0);
                        snap.predict_batch(&xs, &mut res.predictions[start..]);
                        stats.predicted.fetch_add(n as u64, Ordering::Relaxed);
                        stats.lockfree_predicts.fetch_add(n as u64, Ordering::Relaxed);
                    }
                }
            }
            res
        })
    }

    /// Install a snapshot under `session` and wait for the confirmation.
    pub fn restore_sync(&self, session: u64, snapshot: String) -> Result<()> {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(Request::Restore { session, snapshot, resp: tx })?;
        match rx.recv()? {
            Response::Restored => Ok(()),
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }
}

fn router_loop(
    queue: Arc<BoundedQueue<Request>>,
    sessions: Arc<SessionStore>,
    stats: Arc<ServiceStats>,
    registry: Arc<MapRegistry>,
    next_id: Arc<AtomicU64>,
    executor: Option<ExecutorHandle>,
    cfg: ServiceConfig,
) {
    // per-worker buffers: reused across every native predict batch this
    // worker serves (grow to the largest burst, then allocation-free)
    let mut scratch = PredictScratch::default();
    loop {
        // first_wait keeps idle workers parked cheaply; the short gather
        // window lets request bursts coalesce into real batches.
        let batch = match queue.pop_batch_gather(cfg.max_batch, cfg.first_wait, cfg.batch_wait)
        {
            Ok(b) => b,
            Err(_) => return, // closed and drained
        };
        if batch.is_empty() {
            continue;
        }
        // chaos harness: a configured stall makes this worker "slow",
        // letting deadline expiry and queue saturation actually happen
        // under loopback latencies
        #[cfg(any(test, feature = "fault-injection"))]
        if let Some(stall) = cfg.fault_stall {
            std::thread::sleep(stall);
        }
        // Partition: trains/flushes execute immediately; predicts gather
        // for the dynamic batcher.
        let mut predicts: Vec<(u64, Vec<f64>, Sender<Response>, RequestContext)> = Vec::new();
        for req in batch {
            match req {
                Request::Train { session, x, y, resp, ctx } => {
                    if drop_dead_at_dequeue(&stats, &ctx, &resp) {
                        continue;
                    }
                    let t0 = Instant::now();
                    // per-session lock only: trains on other sessions in
                    // other workers proceed in parallel
                    let out = match sessions.get(session) {
                        Some(cell) => {
                            let mut s = lock_counted(&cell, &stats);
                            let r = s.train(&x, y).map(Response::Trained);
                            if r.is_ok() {
                                // commit: publish the new θ for the
                                // lock-free predict path before releasing
                                // the lock (and before responding)
                                cell.republish(&s);
                            }
                            r
                        }
                        None => Err(anyhow::anyhow!("no session {session}")),
                    };
                    if out.is_ok() {
                        stats.trained.fetch_add(1, Ordering::Relaxed);
                    }
                    respond_ctx(&stats, &ctx, resp, out);
                    observe(&stats.latency.train, t0.elapsed());
                }
                Request::TrainBatch { session, xs, ys, resp, ctx } => {
                    if drop_dead_at_dequeue(&stats, &ctx, &resp) {
                        continue;
                    }
                    let t0 = Instant::now();
                    let rows = ys.len() as u64;
                    let out = match sessions.get(session) {
                        Some(cell) => {
                            let mut s = lock_counted(&cell, &stats);
                            let r = s.train_batch(&xs, &ys).map(Response::Trained);
                            if r.is_ok() {
                                cell.republish(&s);
                            }
                            r
                        }
                        None => Err(anyhow::anyhow!("no session {session}")),
                    };
                    let ok = out.is_ok();
                    if ok {
                        // rows, not requests — n rows here count the same
                        // as n single Train requests
                        stats.trained.fetch_add(rows, Ordering::Relaxed);
                    }
                    respond_ctx(&stats, &ctx, resp, out);
                    observe_n(&stats.latency.train, t0.elapsed(), if ok { rows.max(1) } else { 1 });
                }
                Request::TrainDiffusion { group, xs, ys, resp, ctx } => {
                    if drop_dead_at_dequeue(&stats, &ctx, &resp) {
                        continue;
                    }
                    let t0 = Instant::now();
                    let rows = ys.len() as u64;
                    let out = match sessions.get(group) {
                        Some(cell) => {
                            let mut s = lock_counted(&cell, &stats);
                            let r = s.train_diffusion(&xs, &ys).map(Response::Trained);
                            if r.is_ok() {
                                cell.republish(&s);
                            }
                            r
                        }
                        None => Err(anyhow::anyhow!("no session {group}")),
                    };
                    let ok = out.is_ok();
                    if ok {
                        // node-rows: rounds × nodes per request, matching
                        // the group's samples_seen accounting
                        stats.diffusion_rows.fetch_add(rows, Ordering::Relaxed);
                    }
                    respond_ctx(&stats, &ctx, resp, out);
                    observe_n(&stats.latency.train, t0.elapsed(), if ok { rows.max(1) } else { 1 });
                }
                Request::Flush { session, resp } => {
                    let t0 = Instant::now();
                    let out = match sessions.get(session) {
                        Some(cell) => {
                            let mut s = lock_counted(&cell, &stats);
                            let r = s.flush().map(Response::Trained);
                            if r.is_ok() {
                                cell.republish(&s);
                            }
                            r
                        }
                        None => Err(anyhow::anyhow!("no session {session}")),
                    };
                    respond(&stats, resp, out);
                    observe(&stats.latency.train, t0.elapsed());
                }
                Request::Snapshot { session, resp } => {
                    let t0 = Instant::now();
                    // resident sessions serialize under their own lock (a
                    // consistent point-in-time state, buffered rows
                    // included, nothing flushed or dispatched); spilled
                    // sessions return the sink's document directly — no
                    // fault-in, no induced eviction
                    let out = match sessions.snapshot_json(session) {
                        Some(text) => Ok(Response::Snapshot(text)),
                        None => Err(anyhow::anyhow!("no session {session}")),
                    };
                    if out.is_ok() {
                        stats.snapshots.fetch_add(1, Ordering::Relaxed);
                    }
                    respond(&stats, resp, out);
                    observe(&stats.latency.snapshot, t0.elapsed());
                }
                Request::Restore { session, snapshot, resp } => {
                    let t0 = Instant::now();
                    // decode outside any lock (it can be large), then one
                    // store insert — replacing any current occupant is the
                    // point (rollback/migration semantics)
                    let out = SessionSnapshot::from_json(&snapshot)
                        .and_then(|snap| {
                            FilterSession::restore(snap, Some(&registry), executor.clone())
                        })
                        .map(|sess| {
                            sessions.insert(session, sess);
                            // an explicit id must never be re-issued by
                            // add_session later — that would silently
                            // clobber the restored session
                            next_id.fetch_max(session.saturating_add(1), Ordering::Relaxed);
                            Response::Restored
                        });
                    if out.is_ok() {
                        stats.restored.fetch_add(1, Ordering::Relaxed);
                    }
                    respond(&stats, resp, out);
                    observe(&stats.latency.restore, t0.elapsed());
                }
                Request::PredictBatch { session, xs, resp, ctx } => {
                    if drop_dead_at_dequeue(&stats, &ctx, &resp) {
                        continue;
                    }
                    let t0 = Instant::now();
                    // the pre-batched predict path: serve the whole batch
                    // off the lock-free published state via one blocked
                    // kernel call — no per-row gathering, no session lock
                    let out = match sessions.get(session) {
                        Some(cell) => {
                            let snap = cell.predict_handle();
                            drop(cell);
                            let dim = snap.dim();
                            if xs.len() % dim != 0 {
                                Err(anyhow::anyhow!(
                                    "predict probes ({} values) not a multiple of dim {dim} \
                                     for session {session}",
                                    xs.len()
                                ))
                            } else {
                                let n = xs.len() / dim;
                                let mut ys = vec![0.0; n];
                                snap.predict_batch(&xs, &mut ys);
                                stats.predicted.fetch_add(n as u64, Ordering::Relaxed);
                                stats.lockfree_predicts.fetch_add(n as u64, Ordering::Relaxed);
                                Ok(Response::Predictions(ys))
                            }
                        }
                        None => Err(anyhow::anyhow!("no session {session}")),
                    };
                    let rows = match &out {
                        Ok(Response::Predictions(ys)) => ys.len().max(1) as u64,
                        _ => 1,
                    };
                    respond_ctx(&stats, &ctx, resp, out);
                    observe_n(&stats.latency.predict, t0.elapsed(), rows);
                }
                Request::Predict { session, x, resp, ctx } => {
                    predicts.push((session, x, resp, ctx))
                }
            }
        }
        if !predicts.is_empty() {
            dispatch_predicts(&sessions, &stats, executor.as_ref(), predicts, &mut scratch);
        }
    }
}

/// Per-router-worker reusable buffers for the native predict fallback:
/// the gathered row-major probe matrix and the prediction output. Both
/// grow to the largest burst served, then stay allocation-free.
#[derive(Default)]
struct PredictScratch {
    xs: Vec<f64>,
    out: Vec<f64>,
}

fn respond(stats: &ServiceStats, tx: Sender<Response>, out: Result<Response>) {
    let msg = match out {
        Ok(r) => r,
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            Response::Error(e.to_string())
        }
    };
    send_tracked(stats, &tx, msg);
}

/// Send a response, counting an undeliverable one (receiver already
/// dropped — client gone mid-request) under
/// [`ServiceStats::dropped_responses`] instead of discarding the error.
/// The operation already ran; this is delivery accounting only.
fn send_tracked(stats: &ServiceStats, tx: &Sender<Response>, msg: Response) {
    if tx.send(msg).is_err() {
        stats.dropped_responses.fetch_add(1, Ordering::Relaxed);
    }
}

/// Boundary check at router dequeue (and queue shed): resolve a request
/// whose context is already dead without running it. Cancelled-while-
/// queued work gets the diagnostic error reply the cancel contract
/// promises; expired work gets a counted suppressed drop. Returns true
/// when the request was resolved here — exactly one counter moves and
/// exactly one response is sent.
fn drop_dead_at_dequeue(stats: &ServiceStats, ctx: &RequestContext, resp: &Sender<Response>) -> bool {
    if ctx.is_cancelled() {
        stats.cancelled.fetch_add(1, Ordering::Relaxed);
        send_tracked(
            stats,
            resp,
            Response::Error(format!(
                "request {} cancelled before execution",
                ctx.correlation_id
            )),
        );
        true
    } else if ctx.is_expired() {
        stats.deadline_drops.fetch_add(1, Ordering::Relaxed);
        send_tracked(stats, resp, Response::Dropped(DropKind::Deadline));
        true
    } else {
        false
    }
}

/// [`respond`], suppressing the reply when the request died while its
/// work ran: in-flight work always completes (cancellation never
/// interrupts a kernel — θ stays consistent, `samples_seen` stays
/// exact), but a reply nobody is waiting for is not delivered late —
/// it resolves as a counted [`Response::Dropped`] instead. A suppressed
/// execution error is likewise hidden (and not counted under `errors`);
/// the per-session `samples_seen` remains the applied-rows ground truth.
fn respond_ctx(stats: &ServiceStats, ctx: &RequestContext, tx: Sender<Response>, out: Result<Response>) {
    if ctx.is_cancelled() {
        stats.cancelled.fetch_add(1, Ordering::Relaxed);
        send_tracked(stats, &tx, Response::Dropped(DropKind::Cancelled));
    } else if ctx.is_expired() {
        stats.deadline_drops.fetch_add(1, Ordering::Relaxed);
        send_tracked(stats, &tx, Response::Dropped(DropKind::Deadline));
    } else {
        respond(stats, tx, out);
    }
}

/// Deliver one computed row of a gathered predict group, suppressing it
/// when the row's request died while the group ran — the per-row dual
/// of [`respond_ctx`].
fn deliver_row(stats: &ServiceStats, ctx: &RequestContext, tx: &Sender<Response>, msg: Response) {
    if ctx.is_cancelled() {
        stats.cancelled.fetch_add(1, Ordering::Relaxed);
        send_tracked(stats, tx, Response::Dropped(DropKind::Cancelled));
    } else if ctx.is_expired() {
        stats.deadline_drops.fetch_add(1, Ordering::Relaxed);
        send_tracked(stats, tx, Response::Dropped(DropKind::Deadline));
    } else {
        send_tracked(stats, tx, msg);
    }
}

/// Resolve a request shed from the saturated queue (its context is
/// dead): the same counted resolution the dequeue-time boundary gives.
fn resolve_shed(stats: &ServiceStats, req: Request) {
    let (ctx, resp) = match req {
        Request::Train { ctx, resp, .. }
        | Request::TrainBatch { ctx, resp, .. }
        | Request::TrainDiffusion { ctx, resp, .. }
        | Request::Predict { ctx, resp, .. }
        | Request::PredictBatch { ctx, resp, .. } => (ctx, resp),
        // context-less requests are never shed (is_dead() is false)
        Request::Flush { .. } | Request::Snapshot { .. } | Request::Restore { .. } => return,
    };
    drop_dead_at_dequeue(stats, &ctx, &resp);
}

/// Lock a session's mutex, recovering and counting a poisoned one
/// ([`ServiceStats::poisoned_recoveries`]) — a panicked train must not
/// make the session permanently unservable.
fn lock_counted<'a>(
    cell: &'a super::store::SessionCell,
    stats: &ServiceStats,
) -> std::sync::MutexGuard<'a, FilterSession> {
    let (guard, recovered) = cell.lock_tracked();
    if recovered {
        stats.poisoned_recoveries.fetch_add(1, Ordering::Relaxed);
    }
    guard
}

/// Group predicts by session config and, when PJRT is available and the
/// config has a baked `rff_predict` artifact, run each group as one
/// padded batch; otherwise fall back to one **native batched** predict
/// per group ([`super::session::PredictState::predict_batch`] over the
/// worker's reusable scratch).
///
/// Locking: **none**. The session's `(θ, Ω, b)` is loaded from the
/// lock-free published [`PredictState`](super::session::PredictState)
/// (re-stored at every train commit — see
/// [`super::publish::ArcSlot`]), so this function never acquires a
/// session mutex: a predict burst proceeds at full speed even while the
/// session is mid-train, serving the last committed state. Rows served
/// this way count under [`ServiceStats::lockfree_predicts`].
fn dispatch_predicts(
    sessions: &SessionStore,
    stats: &ServiceStats,
    executor: Option<&ExecutorHandle>,
    predicts: Vec<(u64, Vec<f64>, Sender<Response>, RequestContext)>,
    scratch: &mut PredictScratch,
) {
    // Group by (session) first: same session ⇒ same (d, D, Ω). Dead
    // requests resolve at this boundary and never join a group.
    let mut by_session: BTreeMap<u64, Vec<(Vec<f64>, Sender<Response>, RequestContext)>> =
        BTreeMap::new();
    for (sid, x, tx, ctx) in predicts {
        if drop_dead_at_dequeue(stats, &ctx, &tx) {
            continue;
        }
        by_session.entry(sid).or_default().push((x, tx, ctx));
    }
    for (sid, rows) in by_session {
        let t0 = Instant::now();
        let n_in = rows.len() as u64;
        let Some(cell) = sessions.get(sid) else {
            for (_, tx, _) in rows {
                stats.errors.fetch_add(1, Ordering::Relaxed);
                send_tracked(stats, &tx, Response::Error(format!("no session {sid}")));
            }
            observe_n(&stats.latency.predict, t0.elapsed(), n_in);
            continue;
        };
        // wait-free load of the state published at the last train
        // commit — the session mutex is never touched on this path
        let snap = cell.predict_handle();
        drop(cell); // release our cell ref so remove_session() can reclaim it
        let (dim, features) = (snap.dim(), snap.features());
        // reject dim-mismatched probes up front: both predict paths below
        // index x[0..dim] and would panic the router worker otherwise
        let rows: Vec<(Vec<f64>, Sender<Response>, RequestContext)> = rows
            .into_iter()
            .filter_map(|(x, tx, ctx)| {
                if x.len() == dim {
                    Some((x, tx, ctx))
                } else {
                    stats.errors.fetch_add(1, Ordering::Relaxed);
                    send_tracked(
                        stats,
                        &tx,
                        Response::Error(format!(
                            "predict dim mismatch for session {sid}: got {}, want {dim}",
                            x.len()
                        )),
                    );
                    None
                }
            })
            .collect();
        if rows.is_empty() {
            observe_n(&stats.latency.predict, t0.elapsed(), n_in);
            continue;
        }
        let batched = executor.and_then(|eng| {
            let bsz = eng.batch_len("rff_predict", dim, features).ok()?;
            if rows.len() < 2 {
                return None; // single predict: native is cheaper than a dispatch
            }
            Some((eng, bsz))
        });
        match batched {
            Some((eng, bsz)) => {
                let theta = snap.theta_f32();
                // (Ω, b) staging tensors come from the map's shared cached
                // f32 view — built once per map, not per dispatch group
                let view = Arc::clone(snap.map().f32_view());
                // pad each group of up to bsz rows with zeros
                for chunk in rows.chunks(bsz) {
                    let mut x = vec![0.0f32; bsz * dim];
                    for (r, (xi, _, _)) in chunk.iter().enumerate() {
                        for (k, &v) in xi.iter().enumerate() {
                            x[r * dim + k] = v as f32;
                        }
                    }
                    match eng.predict(
                        dim,
                        features,
                        theta.clone(),
                        x,
                        view.omega.clone(),
                        view.phases.clone(),
                    ) {
                        Ok(yhat) => {
                            stats.predict_batches.fetch_add(1, Ordering::Relaxed);
                            stats.predict_rows.fetch_add(chunk.len() as u64, Ordering::Relaxed);
                            stats
                                .lockfree_predicts
                                .fetch_add(chunk.len() as u64, Ordering::Relaxed);
                            for (r, (_, tx, ctx)) in chunk.iter().enumerate() {
                                stats.predicted.fetch_add(1, Ordering::Relaxed);
                                deliver_row(stats, ctx, tx, Response::Predicted(yhat[r] as f64));
                            }
                        }
                        Err(e) => {
                            for (_, tx, _) in chunk {
                                stats.errors.fetch_add(1, Ordering::Relaxed);
                                send_tracked(stats, tx, Response::Error(e.to_string()));
                            }
                        }
                    }
                }
            }
            None => {
                // native fallback serves the whole group through one
                // Z-free blocked batch kernel, gathering rows into and
                // predicting out of the worker's reused buffers — zero
                // steady-state allocations, same values as per-row
                // predicts
                scratch.xs.clear();
                for (x, _, _) in &rows {
                    scratch.xs.extend_from_slice(x);
                }
                if scratch.out.len() < rows.len() {
                    scratch.out.resize(rows.len(), 0.0);
                }
                let out = &mut scratch.out[..rows.len()];
                snap.predict_batch(&scratch.xs, out);
                stats.lockfree_predicts.fetch_add(rows.len() as u64, Ordering::Relaxed);
                for ((_, tx, ctx), &v) in rows.into_iter().zip(out.iter()) {
                    stats.predicted.fetch_add(1, Ordering::Relaxed);
                    deliver_row(stats, &ctx, &tx, Response::Predicted(v));
                }
            }
        }
        observe_n(&stats.latency.predict, t0.elapsed(), n_in);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::{Backend, SessionConfig};
    use crate::rng::run_rng;
    use crate::signal::{NonlinearWiener, SignalSource};

    #[test]
    fn train_predict_roundtrip_native() {
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let mut rng = run_rng(1, 0);
        let s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let sid = svc.add_session(s);
        let mut src = NonlinearWiener::new(run_rng(1, 1), 0.05);
        for smp in src.take_samples(1000) {
            svc.train_sync(sid, smp.x.clone(), smp.y).unwrap();
        }
        let mut src2 = NonlinearWiener::new(run_rng(1, 1), 0.05);
        let probe = src2.take_samples(1100);
        let mse: f64 = probe[1000..]
            .iter()
            .map(|t| {
                let p = svc.predict_sync(sid, t.x.clone()).unwrap();
                (p - t.clean).powi(2)
            })
            .sum::<f64>()
            / 100.0;
        assert!(mse < 1.0, "served-model mse {mse}");
        assert_eq!(svc.stats().trained.load(Ordering::Relaxed), 1000);
        svc.shutdown();
    }

    #[test]
    fn unknown_session_errors() {
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        assert!(svc.train_sync(42, vec![0.0; 5], 1.0).is_err());
        assert_eq!(svc.stats().errors.load(Ordering::Relaxed), 1);
        // failed trains/predicts must not count as successes
        assert_eq!(svc.stats().trained.load(Ordering::Relaxed), 0);
        assert!(svc.predict_sync(42, vec![0.0; 5]).is_err());
        assert_eq!(svc.stats().predicted.load(Ordering::Relaxed), 0);
        assert_eq!(svc.stats().errors.load(Ordering::Relaxed), 2);
        svc.shutdown();
    }

    #[test]
    fn train_batch_counts_rows_and_matches_per_row() {
        use crate::kaf::kernels::Kernel;
        use crate::kaf::RffMap;
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let mut rng = run_rng(7, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 300);
        let cfg = SessionConfig::paper_default();
        let sid_batch =
            svc.add_session(FilterSession::with_map(cfg.clone(), map.clone(), None).unwrap());
        let sid_row = svc.add_session(FilterSession::with_map(cfg, map, None).unwrap());

        let mut src = NonlinearWiener::new(run_rng(7, 1), 0.05);
        let samples = src.take_samples(200);
        let mut want = Vec::new();
        for s in &samples {
            want.extend(svc.train_sync(sid_row, s.x.clone(), s.y).unwrap());
        }
        let mut got = Vec::new();
        for chunk in samples.chunks(48) {
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for s in chunk {
                xs.extend_from_slice(&s.x);
                ys.push(s.y);
            }
            got.extend(svc.train_batch_sync(sid_batch, xs, ys).unwrap());
        }
        assert_eq!(got, want, "batched service training must match per-row bitwise");
        // trained counts rows for both paths: 200 + 200
        assert_eq!(svc.stats().trained.load(Ordering::Relaxed), 400);

        // served predictions agree bitwise across the two sessions
        let probe = samples[0].x.clone();
        assert_eq!(
            svc.predict_sync(sid_batch, probe.clone()).unwrap(),
            svc.predict_sync(sid_row, probe).unwrap()
        );

        // failed batches count zero rows
        assert!(svc.train_batch_sync(999, vec![0.0; 5], vec![1.0]).is_err());
        assert!(svc.train_batch_sync(sid_batch, vec![0.0; 7], vec![1.0]).is_err());
        assert_eq!(svc.stats().trained.load(Ordering::Relaxed), 400);
        assert_eq!(svc.stats().errors.load(Ordering::Relaxed), 2);
        svc.shutdown();
    }

    #[test]
    fn burst_of_predicts_served_batched_natively() {
        // the native fallback serves bursts through predict_batch; the
        // values must equal direct per-row session predicts
        let svc = CoordinatorService::start(
            ServiceConfig {
                workers: 1,
                batch_wait: Duration::from_millis(2),
                ..ServiceConfig::default()
            },
            None,
        );
        let mut rng = run_rng(8, 0);
        let mut s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let mut src = NonlinearWiener::new(run_rng(8, 1), 0.05);
        for smp in src.take_samples(400) {
            s.train(&smp.x, smp.y).unwrap();
        }
        let sid = svc.add_session(s);
        let probes = src.take_samples(64);
        let (tx, rx) = std::sync::mpsc::channel();
        for p in &probes {
            svc.submit(Request::Predict {
                session: sid,
                x: p.x.clone(),
                resp: tx.clone(),
                ctx: RequestContext::default(),
            })
                .unwrap();
        }
        drop(tx);
        let mut served = Vec::new();
        while let Ok(r) = rx.recv() {
            match r {
                Response::Predicted(v) => served.push(v),
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(served.len(), 64);
        let sess = svc.remove_session(sid).unwrap();
        let mut want: Vec<f64> = probes.iter().map(|p| sess.predict(&p.x)).collect();
        let mut got = served;
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(got, want, "batched native serving must match per-row predicts bitwise");
        assert_eq!(svc.stats().predicted.load(Ordering::Relaxed), 64);
        svc.shutdown();
    }

    #[test]
    fn store_shard_count_follows_config() {
        let svc = CoordinatorService::start(
            ServiceConfig { shards: 5, ..ServiceConfig::default() },
            None,
        );
        assert_eq!(svc.store().shard_count(), 8); // rounded up to 2^k
        svc.shutdown();
    }

    #[test]
    fn remove_session_flushes_buffered_chunk_rows() {
        // regression: remove used to hand the session back with up to
        // chunk_n − 1 trained rows still sitting in the PJRT buffer —
        // silently dropped unless the caller knew to flush
        let handle = ExecutorHandle::failing_stub(64);
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let cfg = SessionConfig { backend: Backend::Pjrt, ..SessionConfig::paper_default() };
        let mut rng = run_rng(20, 0);
        let sid =
            svc.add_session(FilterSession::new(cfg, &mut rng, Some(handle)).unwrap());
        let mut src = NonlinearWiener::new(run_rng(20, 1), 0.05);
        for smp in src.take_samples(5) {
            assert!(svc.train_sync(sid, smp.x.clone(), smp.y).unwrap().is_empty());
        }
        let s = svc.remove_session(sid).unwrap();
        // the 5 buffered rows were applied through the native kernels
        assert_eq!(s.samples_seen(), 5);
        assert!(s.running_mse() > 0.0);
        svc.shutdown();
    }

    #[test]
    fn resident_cap_evicts_and_restores_transparently() {
        let svc = CoordinatorService::start(
            ServiceConfig { workers: 2, max_resident_sessions: 2, ..ServiceConfig::default() },
            None,
        );
        let cfg = SessionConfig { features: 16, ..SessionConfig::paper_default() };
        let ids: Vec<u64> = (0..5)
            .map(|_| svc.add_session_from_spec(cfg.clone(), 7).unwrap())
            .collect();
        // the fleet shares ONE interned map
        assert_eq!(svc.registry().len(), 1);
        assert_eq!(svc.session_count(), 5);
        assert_eq!(svc.store().resident_count(), 2);
        // train every session round-robin — touches restore spilled
        // sessions transparently
        let mut src = NonlinearWiener::new(run_rng(21, 1), 0.05);
        for smp in src.take_samples(40) {
            for &sid in &ids {
                svc.train_sync(sid, smp.x.clone(), smp.y).unwrap();
            }
        }
        assert_eq!(svc.stats().errors.load(Ordering::Relaxed), 0);
        let spill = &svc.stats().spill;
        assert!(spill.evictions.load(Ordering::Relaxed) > 0, "no eviction happened");
        assert_eq!(spill.restore_failures.load(Ordering::Relaxed), 0);
        // exact per-session row counts survived the churn
        for &sid in &ids {
            let s = svc.remove_session(sid).unwrap();
            assert_eq!(s.samples_seen(), 40, "session {sid} lost rows");
        }
        // every eviction was eventually matched by a restore
        assert_eq!(
            spill.evictions.load(Ordering::Relaxed),
            spill.restores.load(Ordering::Relaxed)
        );
        svc.shutdown();
    }

    #[test]
    fn snapshot_restore_requests_roundtrip() {
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let mut rng = run_rng(22, 0);
        let cfg = SessionConfig { features: 16, ..SessionConfig::paper_default() };
        let sid = svc.add_session(FilterSession::new(cfg, &mut rng, None).unwrap());
        let mut src = NonlinearWiener::new(run_rng(22, 1), 0.05);
        let samples = src.take_samples(60);
        for smp in &samples[..30] {
            svc.train_sync(sid, smp.x.clone(), smp.y).unwrap();
        }
        let checkpoint = svc.snapshot_sync(sid).unwrap();
        // diverge the live session, then roll it back
        for smp in &samples[30..] {
            svc.train_sync(sid, smp.x.clone(), smp.y).unwrap();
        }
        let diverged = svc.predict_sync(sid, samples[0].x.clone()).unwrap();
        svc.restore_sync(sid, checkpoint.clone()).unwrap();
        let rolled_back = svc.predict_sync(sid, samples[0].x.clone()).unwrap();
        assert_ne!(diverged, rolled_back, "restore did not roll the state back");
        // ...and migration: install the checkpoint under a fresh id
        let clone_id = 777;
        svc.restore_sync(clone_id, checkpoint).unwrap();
        assert_eq!(
            svc.predict_sync(clone_id, samples[0].x.clone()).unwrap(),
            rolled_back,
            "migrated session must serve identical predictions"
        );
        assert_eq!(svc.stats().snapshots.load(Ordering::Relaxed), 1);
        assert_eq!(svc.stats().restored.load(Ordering::Relaxed), 2);
        // regression: restoring under an explicit id advances the id
        // allocator past it — a later add_session must never re-issue
        // id 777 and silently clobber the migrated session
        let mut rng2 = run_rng(23, 0);
        let fresh = svc.add_session(
            FilterSession::new(SessionConfig::paper_default(), &mut rng2, None).unwrap(),
        );
        assert!(fresh > clone_id, "id allocator re-issued a restored id");
        assert_eq!(svc.session_count(), 3);
        // bad documents are an error, not a worker panic
        assert!(svc.restore_sync(1, "{".into()).is_err());
        assert!(svc.snapshot_sync(999).is_err());
        svc.shutdown();
    }

    #[test]
    fn diffusion_group_served_through_the_coordinator() {
        use crate::distributed::{DiffusionOrdering, NetworkTopology};
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let cfg = DiffusionGroupConfig {
            session: SessionConfig { features: 32, ..SessionConfig::paper_default() },
            ordering: DiffusionOrdering::AdaptThenCombine,
            topology: NetworkTopology::ring(4),
        };
        let gid = svc.add_diffusion_group(cfg, 11).unwrap();
        // a same-spec plain session shares the group's interned map
        let scfg = SessionConfig { features: 32, ..SessionConfig::paper_default() };
        let sid = svc.add_session_from_spec(scfg, 11).unwrap();
        assert_eq!(svc.registry().len(), 1);

        let mut src = NonlinearWiener::new(run_rng(40, 1), 0.05);
        let mut rows = 0u64;
        for s in src.take_samples(50) {
            let mut xs = Vec::new();
            for _ in 0..4 {
                xs.extend_from_slice(&s.x);
            }
            let errs = svc.train_diffusion_sync(gid, xs, vec![s.y; 4]).unwrap();
            assert_eq!(errs.len(), 4);
            rows += 4;
            svc.train_sync(sid, s.x.clone(), s.y).unwrap();
        }
        // diffusion rows and filter rows are counted separately
        assert_eq!(svc.stats().diffusion_rows.load(Ordering::Relaxed), rows);
        assert_eq!(svc.stats().trained.load(Ordering::Relaxed), 50);

        // group predictions serve the consensus mean through the
        // ordinary predict path
        let probe = vec![0.1, 0.2, -0.3, 0.0, 0.4];
        let p = svc.predict_sync(gid, probe.clone()).unwrap();
        assert!(p.is_finite());

        // TrainDiffusion against a plain session or an unknown id is an
        // error that counts no rows
        assert!(svc.train_diffusion_sync(sid, vec![0.0; 20], vec![0.0; 4]).is_err());
        assert!(svc.train_diffusion_sync(999, vec![0.0; 20], vec![0.0; 4]).is_err());
        assert_eq!(svc.stats().diffusion_rows.load(Ordering::Relaxed), rows);
        assert_eq!(svc.stats().errors.load(Ordering::Relaxed), 2);

        // group snapshots flow through the request API: migrate the
        // group under a new id, predictions agree bitwise
        let snap = svc.snapshot_sync(gid).unwrap();
        svc.restore_sync(777, snap).unwrap();
        assert_eq!(svc.predict_sync(777, probe).unwrap(), p);

        let g = svc.remove_session(gid).unwrap();
        assert_eq!(g.samples_seen(), rows as usize);
        assert!(g.diffusion().is_some());
        svc.shutdown();
    }

    #[test]
    fn predicts_serve_published_state_without_locks() {
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let mut rng = run_rng(30, 0);
        let s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let sid = svc.add_session(s);
        let mut src = NonlinearWiener::new(run_rng(30, 1), 0.05);
        let samples = src.take_samples(50);
        // a fresh session already has a published state (θ = 0): a
        // predict racing the very first train is valid, not a panic
        assert_eq!(svc.predict_sync(sid, samples[0].x.clone()).unwrap(), 0.0);
        for smp in &samples {
            svc.train_sync(sid, smp.x.clone(), smp.y).unwrap();
        }
        let served = svc.predict_sync(sid, samples[0].x.clone()).unwrap();
        // both predicts went through the lock-free path...
        assert_eq!(svc.stats().lockfree_predicts.load(Ordering::Relaxed), 2);
        // ...and the second served exactly the last committed θ
        let sess = svc.remove_session(sid).unwrap();
        assert_eq!(served, sess.predict(&samples[0].x));
        svc.shutdown();
    }

    #[test]
    fn run_epoch_is_deterministic_across_worker_counts() {
        let make = || {
            let svc = CoordinatorService::start(ServiceConfig::default(), None);
            let cfg = SessionConfig { features: 16, ..SessionConfig::paper_default() };
            let ids: Vec<u64> = (0..6)
                .map(|i| svc.add_session_from_spec(cfg.clone(), 9 + i).unwrap())
                .collect();
            (svc, ids)
        };
        let traffic_for = |ids: &[u64]| -> Vec<SessionTraffic> {
            ids.iter()
                .enumerate()
                .map(|(k, &sid)| {
                    let mut src = NonlinearWiener::new(run_rng(60 + k as u64, 1), 0.05);
                    let mut ops = Vec::new();
                    for _ in 0..3 {
                        let batch = src.take_samples(15);
                        let mut xs = Vec::new();
                        let mut ys = Vec::new();
                        for s in &batch {
                            xs.extend_from_slice(&s.x);
                            ys.push(s.y);
                        }
                        let probes: Vec<f64> =
                            batch.iter().take(4).flat_map(|s| s.x.clone()).collect();
                        ops.push(EpochOp::TrainBatch { xs, ys });
                        // served off the state just committed above
                        ops.push(EpochOp::PredictBatch { xs: probes });
                    }
                    SessionTraffic { session: sid, ops }
                })
                .collect()
        };
        let mut reference: Option<Vec<SessionEpochResult>> = None;
        for workers in [1usize, 2, 8] {
            let (svc, ids) = make();
            let out = svc.run_epoch(traffic_for(&ids), workers);
            assert_eq!(out.len(), ids.len());
            for r in &out {
                assert!(r.failed.is_none(), "workers={workers}: {:?}", r.failed);
            }
            // exact row accounting: 3 × 15 train rows and 3 × 4 predict
            // rows per session, every predict via the lock-free path
            assert_eq!(svc.stats().trained.load(Ordering::Relaxed), 6 * 45);
            assert_eq!(svc.stats().predicted.load(Ordering::Relaxed), 6 * 12);
            assert_eq!(svc.stats().lockfree_predicts.load(Ordering::Relaxed), 6 * 12);
            for &sid in &ids {
                assert_eq!(svc.remove_session(sid).unwrap().samples_seen(), 45);
            }
            match &reference {
                None => reference = Some(out),
                // bitwise: errors AND predictions, every session
                Some(want) => assert_eq!(&out, want, "workers={workers} diverged"),
            }
            svc.shutdown();
        }
    }

    #[test]
    fn run_epoch_reports_per_session_failures() {
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let cfg = SessionConfig { features: 16, ..SessionConfig::paper_default() };
        let sid = svc.add_session_from_spec(cfg, 3).unwrap();
        let out = svc.run_epoch(
            vec![
                SessionTraffic {
                    session: sid,
                    ops: vec![
                        // dim mismatch: fails, skipping the rest of THIS
                        // session's ops only
                        EpochOp::TrainBatch { xs: vec![0.0; 7], ys: vec![1.0] },
                        EpochOp::PredictBatch { xs: vec![0.0; 5] },
                    ],
                },
                SessionTraffic {
                    session: 999, // unknown id
                    ops: vec![EpochOp::PredictBatch { xs: vec![0.0; 5] }],
                },
            ],
            2,
        );
        assert!(out[0].failed.is_some());
        assert!(out[0].predictions.is_empty(), "ops after a failure must not run");
        assert!(out[1].failed.as_deref().unwrap().contains("no session"));
        assert_eq!(svc.stats().errors.load(Ordering::Relaxed), 2);
        assert_eq!(svc.stats().trained.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn predict_batch_request_matches_per_row() {
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let mut rng = run_rng(55, 0);
        let s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let sid = svc.add_session(s);
        let mut src = NonlinearWiener::new(run_rng(55, 1), 0.05);
        let samples = src.take_samples(120);
        for smp in &samples[..100] {
            svc.train_sync(sid, smp.x.clone(), smp.y).unwrap();
        }
        let probes = &samples[100..];
        let xs: Vec<f64> = probes.iter().flat_map(|s| s.x.clone()).collect();
        let got = svc.predict_batch_sync(sid, xs).unwrap();
        let want: Vec<f64> = probes
            .iter()
            .map(|p| svc.predict_sync(sid, p.x.clone()).unwrap())
            .collect();
        assert_eq!(got, want, "PredictBatch must match per-row predicts bitwise");
        // rows counted (20 batched + 20 single), every one lock-free
        assert_eq!(svc.stats().predicted.load(Ordering::Relaxed), 40);
        assert_eq!(svc.stats().lockfree_predicts.load(Ordering::Relaxed), 40);
        // ragged probes and unknown sessions error without counting rows
        assert!(svc.predict_batch_sync(sid, vec![0.0; 7]).is_err());
        assert!(svc.predict_batch_sync(999, vec![0.0; 5]).is_err());
        assert_eq!(svc.stats().predicted.load(Ordering::Relaxed), 40);
        assert_eq!(svc.stats().errors.load(Ordering::Relaxed), 2);
        svc.shutdown();
    }

    #[test]
    fn dropped_receiver_counts_dropped_responses() {
        // regression: a client hanging up mid-request used to discard
        // the send error invisibly — disconnect storms were unobservable
        let svc = CoordinatorService::start(
            ServiceConfig { workers: 1, ..ServiceConfig::default() },
            None,
        );
        let mut rng = run_rng(66, 0);
        let sid = svc
            .add_session(FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap());
        // a train whose requester is gone before the response sends...
        {
            let (tx, rx) = std::sync::mpsc::channel();
            drop(rx);
            svc.submit(Request::Train {
                session: sid,
                x: vec![0.0; 5],
                y: 1.0,
                resp: tx,
                ctx: RequestContext::default(),
            })
                .unwrap();
        }
        // ...and a predict delivered through dispatch_predicts
        {
            let (tx, rx) = std::sync::mpsc::channel();
            drop(rx);
            svc.submit(Request::Predict {
                session: sid,
                x: vec![0.0; 5],
                resp: tx,
                ctx: RequestContext::default(),
            })
            .unwrap();
        }
        // a sync call queued behind them on the single worker is a
        // barrier: once it returns, both dropped sends have happened
        svc.predict_sync(sid, vec![0.0; 5]).unwrap();
        assert_eq!(svc.stats().dropped_responses.load(Ordering::Relaxed), 2);
        // the operations themselves still ran as successes
        assert_eq!(svc.stats().trained.load(Ordering::Relaxed), 1);
        assert_eq!(svc.stats().errors.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn latency_histograms_record_per_class_and_per_row() {
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let mut rng = run_rng(77, 0);
        let cfg = SessionConfig { features: 16, ..SessionConfig::paper_default() };
        let sid = svc.add_session(FilterSession::new(cfg, &mut rng, None).unwrap());
        let mut src = NonlinearWiener::new(run_rng(77, 1), 0.05);
        let samples = src.take_samples(15);
        for smp in &samples[..10] {
            svc.train_sync(sid, smp.x.clone(), smp.y).unwrap();
        }
        let (mut xs, mut ys) = (Vec::new(), Vec::new());
        for smp in &samples[10..] {
            xs.extend_from_slice(&smp.x);
            ys.push(smp.y);
        }
        svc.train_batch_sync(sid, xs, ys).unwrap(); // 5 rows, one request
        for smp in &samples[..3] {
            svc.predict_sync(sid, smp.x.clone()).unwrap();
        }
        let probe4: Vec<f64> = samples[..4].iter().flat_map(|s| s.x.clone()).collect();
        svc.predict_batch_sync(sid, probe4).unwrap();
        let snap = svc.snapshot_sync(sid).unwrap();
        svc.restore_sync(sid, snap).unwrap();
        let lat = &svc.stats().latency;
        // batched requests record per ROW: 10 singles + 5 batched
        assert_eq!(lat.train.lock().unwrap().count(), 15);
        // 3 single predicts + a 4-row batch
        assert_eq!(lat.predict.lock().unwrap().count(), 7);
        assert_eq!(lat.snapshot.lock().unwrap().count(), 1);
        assert_eq!(lat.restore.lock().unwrap().count(), 1);
        assert!(lat.train.lock().unwrap().quantile(0.99) > 0.0);
        // Debug impl renders the report lines without panicking
        assert!(format!("{:?}", svc.stats().latency).contains("p50"));
        svc.shutdown();
    }

    #[test]
    fn try_submit_accepts_when_capacity_allows() {
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let mut rng = run_rng(88, 0);
        let sid = svc
            .add_session(FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap());
        assert_eq!(svc.queue_capacity(), 1024);
        let (tx, rx) = std::sync::mpsc::channel();
        let accepted = svc
            .try_submit(Request::Predict {
                session: sid,
                x: vec![0.0; 5],
                resp: tx,
                ctx: RequestContext::default(),
            })
            .unwrap();
        assert!(accepted, "empty queue must accept a try_submit");
        assert!(matches!(rx.recv().unwrap(), Response::Predicted(_)));
        svc.shutdown();
    }

    #[test]
    fn concurrent_sessions_do_not_interfere() {
        let svc = Arc::new(CoordinatorService::start(ServiceConfig::default(), None));
        let mut ids = Vec::new();
        for i in 0..8 {
            let mut rng = run_rng(100 + i, 0);
            let s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
            ids.push(svc.add_session(s));
        }
        let handles: Vec<_> = ids
            .iter()
            .map(|&sid| {
                let svc = Arc::clone(&svc);
                std::thread::spawn(move || {
                    let mut src = NonlinearWiener::new(run_rng(sid, 1), 0.05);
                    for smp in src.take_samples(300) {
                        svc.train_sync(sid, smp.x.clone(), smp.y).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(svc.stats().trained.load(Ordering::Relaxed), 8 * 300);
        assert_eq!(svc.session_count(), 8);
        if let Ok(s) = Arc::try_unwrap(svc) {
            s.shutdown();
        }
    }

    #[test]
    fn poisoned_session_recovers_and_counts_once() {
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let mut rng = run_rng(77, 0);
        let s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let sid = svc.add_session(s);
        svc.train_sync(sid, vec![0.1; 5], 0.5).unwrap();
        // poison the session mutex: a holder panics "mid-train"
        let cell = svc.sessions.get(sid).unwrap();
        let poisoner = Arc::clone(&cell);
        drop(cell);
        let h = std::thread::spawn(move || {
            let _guard = poisoner.lock();
            panic!("simulated mid-train panic");
        });
        assert!(h.join().is_err());
        // the same session must train successfully again…
        svc.train_sync(sid, vec![0.2; 5], 0.5).unwrap();
        assert_eq!(svc.stats().poisoned_recoveries.load(Ordering::Relaxed), 1);
        // …and the incident counts once, not once per subsequent lock
        svc.train_sync(sid, vec![0.3; 5], 0.5).unwrap();
        assert_eq!(svc.stats().poisoned_recoveries.load(Ordering::Relaxed), 1);
        assert_eq!(svc.stats().trained.load(Ordering::Relaxed), 3);
        svc.shutdown();
    }

    #[test]
    fn expired_deadline_drops_at_dequeue() {
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let mut rng = run_rng(78, 0);
        let s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let sid = svc.add_session(s);
        let (tx, rx) = std::sync::mpsc::channel();
        let ctx = RequestContext {
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            ..RequestContext::default()
        };
        svc.submit(Request::Train { session: sid, x: vec![0.0; 5], y: 1.0, resp: tx, ctx })
            .unwrap();
        match rx.recv().unwrap() {
            Response::Dropped(DropKind::Deadline) => {}
            other => panic!("expected a suppressed deadline drop, got {other:?}"),
        }
        assert_eq!(svc.stats().deadline_drops.load(Ordering::Relaxed), 1);
        // the work never ran — no row applied, no error counted
        assert_eq!(svc.stats().trained.load(Ordering::Relaxed), 0);
        assert_eq!(svc.stats().errors.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn cancelled_queued_request_gets_diagnostic() {
        let svc = CoordinatorService::start(ServiceConfig::default(), None);
        let mut rng = run_rng(79, 0);
        let s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let sid = svc.add_session(s);
        let (tx, rx) = std::sync::mpsc::channel();
        let flag = Arc::new(AtomicBool::new(true));
        let ctx = RequestContext {
            cancelled: Some(flag),
            correlation_id: 7,
            ..RequestContext::default()
        };
        svc.submit(Request::Predict { session: sid, x: vec![0.0; 5], resp: tx, ctx }).unwrap();
        match rx.recv().unwrap() {
            Response::Error(msg) => {
                assert!(msg.contains("cancelled"), "diagnostic should name the cancel: {msg}");
                assert!(msg.contains('7'), "diagnostic should carry the correlation id: {msg}");
            }
            other => panic!("queued cancel must get a diagnostic reply, got {other:?}"),
        }
        assert_eq!(svc.stats().cancelled.load(Ordering::Relaxed), 1);
        assert_eq!(svc.stats().predicted.load(Ordering::Relaxed), 0);
        svc.shutdown();
    }

    #[test]
    fn respond_ctx_suppresses_dead_replies() {
        let stats = ServiceStats::default();
        // cancelled in flight: reply suppressed, counted under cancelled
        let (tx, rx) = std::sync::mpsc::channel();
        let flag = Arc::new(AtomicBool::new(true));
        let ctx = RequestContext { cancelled: Some(flag), ..RequestContext::default() };
        respond_ctx(&stats, &ctx, tx, Ok(Response::Predicted(1.0)));
        assert!(matches!(rx.recv().unwrap(), Response::Dropped(DropKind::Cancelled)));
        assert_eq!(stats.cancelled.load(Ordering::Relaxed), 1);
        // expired in flight: suppressed, counted under deadline_drops
        let (tx, rx) = std::sync::mpsc::channel();
        let ctx = RequestContext {
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            ..RequestContext::default()
        };
        respond_ctx(&stats, &ctx, tx, Ok(Response::Predicted(1.0)));
        assert!(matches!(rx.recv().unwrap(), Response::Dropped(DropKind::Deadline)));
        assert_eq!(stats.deadline_drops.load(Ordering::Relaxed), 1);
        // a live context delivers unchanged
        let (tx, rx) = std::sync::mpsc::channel();
        respond_ctx(&stats, &RequestContext::default(), tx, Ok(Response::Predicted(2.5)));
        assert!(matches!(rx.recv().unwrap(), Response::Predicted(v) if v == 2.5));
        assert_eq!(stats.dropped_responses.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn saturated_queue_sheds_expired_first() {
        // single worker + injected stall: the queue can actually fill
        let svc = CoordinatorService::start(
            ServiceConfig {
                workers: 1,
                queue_capacity: 4,
                fault_stall: Some(Duration::from_millis(300)),
                ..ServiceConfig::default()
            },
            None,
        );
        let mut rng = run_rng(80, 0);
        let s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let sid = svc.add_session(s);
        // occupy the worker: it pops this request, then stalls
        let (busy_tx, busy_rx) = std::sync::mpsc::channel();
        svc.submit(Request::Train {
            session: sid,
            x: vec![0.0; 5],
            y: 0.1,
            resp: busy_tx,
            ctx: RequestContext::default(),
        })
        .unwrap();
        std::thread::sleep(Duration::from_millis(50));
        // fill the queue with already-expired requests
        let expired = RequestContext {
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            ..RequestContext::default()
        };
        let (dead_tx, dead_rx) = std::sync::mpsc::channel();
        for _ in 0..4 {
            assert!(svc
                .try_submit(Request::Train {
                    session: sid,
                    x: vec![0.0; 5],
                    y: 0.0,
                    resp: dead_tx.clone(),
                    ctx: expired.clone(),
                })
                .unwrap());
        }
        // full queue: a live request must shed the dead entries, not bounce
        let (live_tx, live_rx) = std::sync::mpsc::channel();
        let accepted = svc
            .try_submit(Request::Predict {
                session: sid,
                x: vec![0.0; 5],
                resp: live_tx,
                ctx: RequestContext::default(),
            })
            .unwrap();
        assert!(accepted, "live work must displace expired queue entries");
        for _ in 0..4 {
            assert!(matches!(dead_rx.recv().unwrap(), Response::Dropped(DropKind::Deadline)));
        }
        assert!(matches!(live_rx.recv().unwrap(), Response::Predicted(_)));
        assert!(matches!(busy_rx.recv().unwrap(), Response::Trained(_)));
        assert_eq!(svc.stats().deadline_drops.load(Ordering::Relaxed), 4);
        svc.shutdown();
    }
}
