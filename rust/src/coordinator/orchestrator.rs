//! Monte-Carlo experiment orchestrator: runs R independent realizations
//! of (signal, filter) across the thread pool and accumulates the
//! averaged learning curve — the machinery behind every figure of the
//! paper (100 runs for Fig. 1, 1000 for Figs. 2–3).
//!
//! Orchestrator runs own their filters outright (one per realization, no
//! sharing), so they bypass the serving layer's [`super::SessionStore`]
//! locking entirely — `parallel_for` gives each worker exclusive state,
//! which is what keeps MC sweeps scheduling-independent bit-for-bit.

use crate::exec::parallel_for;
use crate::kaf::OnlineRegressor;
use crate::metrics::LearningCurve;
use crate::signal::{SignalFactory, SignalSource};

/// Monte-Carlo configuration.
#[derive(Clone, Copy, Debug)]
pub struct McConfig {
    /// Number of independent realizations.
    pub runs: usize,
    /// Samples per realization.
    pub horizon: usize,
    /// Worker threads (0 ⇒ auto).
    pub workers: usize,
}

impl McConfig {
    /// Standard config with auto worker count.
    pub fn new(runs: usize, horizon: usize) -> Self {
        Self { runs, horizon, workers: 0 }
    }

    fn effective_workers(&self) -> usize {
        if self.workers == 0 {
            crate::exec::default_parallelism()
        } else {
            self.workers
        }
    }
}

/// Result of one Monte-Carlo sweep for one algorithm.
#[derive(Clone, Debug)]
pub struct McResult {
    /// Algorithm label.
    pub name: String,
    /// Averaged learning curve.
    pub curve: LearningCurve,
    /// Mean wall-clock training time per realization (seconds) — the
    /// Table-1 statistic.
    pub mean_train_secs: f64,
    /// Mean final model size (dictionary size M or feature count D).
    pub mean_model_size: f64,
}

impl McResult {
    /// Steady-state MSE over the last tenth of the horizon.
    pub fn steady_state(&self) -> f64 {
        self.curve.steady_state((self.curve.horizon() / 10).max(1))
    }
}

/// The orchestrator: pairs a [`SignalFactory`] with filter builders.
pub struct Orchestrator {
    config: McConfig,
}

impl Orchestrator {
    /// Create with the given MC configuration.
    pub fn new(config: McConfig) -> Self {
        Self { config }
    }

    /// The MC configuration.
    pub fn config(&self) -> &McConfig {
        &self.config
    }

    /// Run `build_filter(run_index)` against `signals` for every run,
    /// averaging curves. The filter builder receives the run index so it
    /// can draw run-specific feature maps (deterministically).
    pub fn run<F, R, S>(&self, name: &str, signals: &S, build_filter: F) -> McResult
    where
        S: SignalFactory,
        F: Fn(usize) -> R + Sync,
        R: OnlineRegressor,
    {
        let cfg = self.config;
        let outputs = parallel_for(cfg.runs, cfg.effective_workers(), |run| {
            let mut src = signals.for_run(run);
            let samples = src.take_samples(cfg.horizon);
            let mut filter = build_filter(run);
            let t = std::time::Instant::now();
            let errors = filter.run(&samples);
            let secs = t.elapsed().as_secs_f64();
            (errors, secs, filter.model_size())
        });
        let mut curve = LearningCurve::new(cfg.horizon);
        let mut time_acc = 0.0;
        let mut size_acc = 0.0;
        for (errors, secs, size) in &outputs {
            curve.add_run(errors);
            time_acc += secs;
            size_acc += *size as f64;
        }
        McResult {
            name: name.to_string(),
            curve,
            mean_train_secs: time_acc / cfg.runs as f64,
            mean_model_size: size_acc / cfg.runs as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kaf::kernels::Kernel;
    use crate::kaf::{RffKlms, RffMap};
    use crate::rng::run_rng;
    use crate::signal::{FnFactory, NonlinearWiener};

    fn factory(seed: u64) -> impl SignalFactory<Source = NonlinearWiener> {
        FnFactory::new(5, move |run| NonlinearWiener::new(run_rng(seed, run), 0.05))
    }

    fn rffklms(run: usize) -> RffKlms {
        let mut rng = run_rng(999, run);
        RffKlms::new(RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 100), 1.0)
    }

    #[test]
    fn mc_sweep_accumulates_all_runs() {
        let orch = Orchestrator::new(McConfig::new(8, 500));
        let res = orch.run("RFF-KLMS", &factory(1), rffklms);
        assert_eq!(res.curve.runs(), 8);
        assert_eq!(res.curve.horizon(), 500);
        assert!(res.mean_train_secs > 0.0);
        assert_eq!(res.mean_model_size, 100.0);
        // learning happened
        let mse = res.curve.mse();
        assert!(mse[499] < mse[0]);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let a = Orchestrator::new(McConfig { runs: 6, horizon: 200, workers: 1 })
            .run("x", &factory(2), rffklms);
        let b = Orchestrator::new(McConfig { runs: 6, horizon: 200, workers: 4 })
            .run("x", &factory(2), rffklms);
        let ma = a.curve.mse();
        let mb = b.curve.mse();
        for (x, y) in ma.iter().zip(&mb) {
            assert!((x - y).abs() < 1e-15, "MC must be scheduling-independent");
        }
    }
}
