//! Sharded, per-session-locked session store with idle-LRU spill — the
//! concurrency and residency substrate of the coordinator service.
//!
//! The paper's fixed-size-θ property means every session is a small,
//! self-contained state with O(D) updates; nothing about one session's
//! train touches another's. The store mirrors that in the lock
//! structure: session ids hash onto `N` shards, each shard is a
//! `Mutex<BTreeMap<u64, Resident>>`, and all mutation of a session
//! happens under that session's *own* mutex.
//!
//! The same property makes sessions *evictable*: a session's complete
//! state serializes to a [`SessionSnapshot`] of known size, so when a
//! resident cap is configured ([`SpillConfig`]) the least-recently-
//! touched session spills to a [`SnapshotSink`] and the store restores
//! it transparently on its next touch. Snapshot → evict → restore →
//! train is bitwise identical to the uninterrupted run (native), so
//! callers cannot observe eviction except through latency and the
//! [`SpillStats`] counters.
//!
//! Locking contract (also documented on [`crate::coordinator`]):
//!
//! * **Shard locks** are held for map operations — insert, remove, id
//!   lookup, len — and for the decode + re-insert of a spilled session
//!   on touch (so a racing double-touch restores exactly once). Never
//!   while training, predicting or dispatching.
//! * **Session locks** are held for exactly one train/flush call —
//!   which, before releasing, republishes the session's
//!   [`PredictState`](super::session::PredictState) into the slot's
//!   lock-free [`ArcSlot`](super::publish::ArcSlot). Predicts read that
//!   published state ([`SessionSlot::predict_handle`]) and take **no
//!   lock at all**.
//! * **The eviction set** (`Mutex<BTreeSet<u64>>`) names sessions whose
//!   spill is in flight: unlinked from their shard but not yet in the
//!   sink. Touches of those ids spin briefly, then restore from the
//!   sink. Acquired only alone or under a shard lock (order: shard →
//!   eviction set); session locks are never taken under either, so
//!   deadlock is impossible.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use crate::kaf::MapRegistry;
use crate::runtime::ExecutorHandle;

use super::publish::ArcSlot;
use super::session::{FilterSession, PredictState};
use super::snapshot::{SessionSnapshot, SnapshotSink};

/// One session's residency unit: the mutable [`FilterSession`] behind
/// its per-session mutex, plus the **lock-free published
/// [`PredictState`]** — an [`ArcSlot`] the train path re-stores at every
/// commit (train/flush/restore, under the session lock, *before*
/// responding) and the predict path loads without ever touching the
/// mutex. Predicts therefore never convoy behind a long train; what they
/// serve is the state as of the last completed commit, which is exactly
/// the consistency train/predict pipelines already had when predicts
/// snapshotted under the lock.
pub(crate) struct SessionSlot {
    session: Mutex<FilterSession>,
    published: ArcSlot<PredictState>,
}

impl SessionSlot {
    /// Wrap a session, publishing its initial predict state (so a predict
    /// racing the very first train still has something valid to serve).
    pub(crate) fn new(session: FilterSession) -> Self {
        let published = ArcSlot::new(Arc::new(session.predict_state()));
        Self { session: Mutex::new(session), published }
    }

    /// Lock the session for train/flush/snapshot. Poison-absorbing: a
    /// panicked trainer leaves θ mid-update at worst, which the next
    /// commit overwrites wholesale.
    pub(crate) fn lock(&self) -> MutexGuard<'_, FilterSession> {
        self.session.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// [`SessionSlot::lock`], reporting whether this acquisition
    /// *recovered* a poisoned mutex (a previous holder panicked). The
    /// poison flag is cleared on recovery so each incident reports
    /// exactly once — the router counts it under
    /// `ServiceStats::poisoned_recoveries` and the session stays
    /// servable.
    pub(crate) fn lock_tracked(&self) -> (MutexGuard<'_, FilterSession>, bool) {
        match self.session.lock() {
            Ok(guard) => (guard, false),
            Err(poisoned) => {
                self.session.clear_poison();
                (poisoned.into_inner(), true)
            }
        }
    }

    /// Publish `session`'s current predict state. Callers pass the
    /// session they already hold locked — taking `&FilterSession` (rather
    /// than locking internally) makes "republish happens under the
    /// session lock, after the mutation, before the response" a
    /// signature-level requirement.
    pub(crate) fn republish(&self, session: &FilterSession) {
        self.published.store(Arc::new(session.predict_state()));
    }

    /// The last published predict state — wait-free, no mutex.
    pub(crate) fn predict_handle(&self) -> Arc<PredictState> {
        self.published.load()
    }

    /// Consume the slot, returning the session by value.
    fn into_session(self) -> FilterSession {
        self.session.into_inner().unwrap_or_else(PoisonError::into_inner)
    }
}

/// A shared session slot handed out by the store.
/// Crate-private: see [`SessionStore::get`] for why cells never escape.
pub(crate) type SessionCell = Arc<SessionSlot>;

/// One resident session: its cell plus the LRU touch stamp (mutated only
/// under the owning shard's lock).
struct Resident {
    cell: SessionCell,
    last_touch: u64,
}

type Shard = Mutex<BTreeMap<u64, Resident>>;

/// Spill policy: the resident cap and where evicted sessions go.
pub struct SpillConfig {
    /// Maximum resident (live, unlocked-or-locked) sessions; the
    /// least-recently-touched session beyond this spills. Must be ≥ 1.
    pub max_resident: usize,
    /// Where snapshots spill to / restore from.
    pub sink: Arc<dyn SnapshotSink>,
    /// Resolves reference-mode map payloads on restore, so restored
    /// sessions share the fleet's interned `(Ω, b)`.
    pub registry: Arc<MapRegistry>,
    /// Needed to rebuild PJRT-backend sessions on restore.
    pub executor: Option<ExecutorHandle>,
    /// Eviction/restore counters (shared with
    /// [`super::ServiceStats`]).
    pub stats: Arc<SpillStats>,
}

/// Spill bookkeeping. Steady-state invariant:
/// `evictions == restores + currently-spilled`; after every session has
/// been removed (removes restore spilled sessions), `evictions ==
/// restores` exactly.
#[derive(Debug, Default)]
pub struct SpillStats {
    /// Sessions evicted to the sink.
    pub evictions: AtomicU64,
    /// Sessions restored from the sink (on touch or removal).
    pub restores: AtomicU64,
    /// Spilled snapshots that failed to load/decode (session stays in
    /// the sink; the touch reported "no session").
    pub restore_failures: AtomicU64,
    /// Evictions whose sink write failed (the session was re-admitted,
    /// not lost).
    pub eviction_failures: AtomicU64,
}

enum Lookup {
    Found(SessionCell),
    /// Found in the sink and re-admitted: caller must re-enforce the cap.
    Restored(SessionCell),
    Absent,
    /// Mid-eviction: unlinked but not yet in the sink — retry shortly.
    MidEviction,
}

/// Sharded map from session id to independently locked [`FilterSession`],
/// with optional idle-LRU spill.
pub struct SessionStore {
    shards: Vec<Shard>,
    /// `shards.len() - 1`; the shard count is a power of two so the
    /// hash→shard reduction is a mask, not a modulo.
    mask: u64,
    /// Monotonic LRU clock (ticks on every touch).
    clock: AtomicU64,
    /// Resident-session count (maintained eagerly so the cap check is a
    /// load, not an all-shards scan).
    resident: AtomicUsize,
    /// Ids whose eviction is in flight. See the module docs.
    evicting: Mutex<BTreeSet<u64>>,
    spill: Option<SpillConfig>,
}

impl SessionStore {
    /// Store with at least `shards` shards (rounded up to a power of two,
    /// minimum 1) and unbounded residency (no spill).
    pub fn new(shards: usize) -> Self {
        Self::build(shards, None)
    }

    /// Store with idle-LRU spill: at most `spill.max_resident` sessions
    /// stay live; the rest round-trip through `spill.sink`.
    pub fn with_spill(shards: usize, spill: SpillConfig) -> Self {
        assert!(spill.max_resident >= 1, "max_resident must be at least 1");
        Self::build(shards, Some(spill))
    }

    fn build(shards: usize, spill: Option<SpillConfig>) -> Self {
        let n = shards.max(1).next_power_of_two();
        Self {
            shards: (0..n).map(|_| Mutex::new(BTreeMap::new())).collect(),
            mask: (n - 1) as u64,
            clock: AtomicU64::new(0),
            resident: AtomicUsize::new(0),
            evicting: Mutex::new(BTreeSet::new()),
            spill,
        }
    }

    /// Number of shards (power of two).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Shard index for `id`. Session ids are sequential, so spread them
    /// with a Fibonacci hash before masking — consecutive ids land on
    /// different shards. Public for diagnostics and so tests exercise
    /// the real hash rather than a reimplementation.
    pub fn shard_index(&self, id: u64) -> usize {
        let h = id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((h >> 32) & self.mask) as usize
    }

    fn shard_for(&self, id: u64) -> &Shard {
        &self.shards[self.shard_index(id)]
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn decode(spill: &SpillConfig, text: &str) -> anyhow::Result<FilterSession> {
        let snap = SessionSnapshot::from_json(text)?;
        FilterSession::restore(snap, Some(&spill.registry), spill.executor.clone())
    }

    /// Insert `session` under `id` (replacing any previous occupant, and
    /// discarding any stale spilled snapshot of the same id). May evict
    /// the LRU session when a cap is configured. Crate-private: ids are
    /// allocated by `CoordinatorService`'s counter; outside inserts could
    /// silently clobber a live session.
    pub(crate) fn insert(&self, id: u64, session: FilterSession) {
        let stamp = self.tick();
        let mut spins = 0u32;
        loop {
            let mut shard = self.shard_for(id).lock().unwrap_or_else(PoisonError::into_inner);
            if let Some(spill) = &self.spill {
                // an in-flight eviction of the same id would land its
                // snapshot in the sink *after* our delete below, leaving a
                // stale spill that a later touch could resurrect — wait it
                // out first (rare: only explicit re-inserts race evictions)
                if self.evicting.lock().unwrap_or_else(PoisonError::into_inner).contains(&id) {
                    drop(shard);
                    Self::backoff(&mut spins);
                    continue;
                }
                // a re-used id must not resurrect a stale snapshot later
                let _ = spill.sink.delete(id);
            }
            let prev = shard.insert(
                id,
                Resident { cell: Arc::new(SessionSlot::new(session)), last_touch: stamp },
            );
            if prev.is_none() {
                self.resident.fetch_add(1, Ordering::Relaxed);
            }
            break;
        }
        self.enforce_cap();
    }

    /// Clone the session cell for `id`, restoring it from the spill sink
    /// if it was evicted. Callers lock the returned cell to train/flush
    /// or snapshot; all store locks are released before this function
    /// returns.
    ///
    /// Crate-private on purpose: a caller that retained a cell while also
    /// calling [`SessionStore::remove`] on the same thread would deadlock
    /// that removal (it waits for the last outside reference to drop), so
    /// cells never leave the crate — router workers hold one per request.
    pub(crate) fn get(&self, id: u64) -> Option<SessionCell> {
        let mut spins = 0u32;
        loop {
            match self.lookup(id) {
                Lookup::Found(cell) => return Some(cell),
                Lookup::Restored(cell) => {
                    // the restore pushed us over the cap: evict someone
                    // (never the just-restored session — it is MRU)
                    self.enforce_cap();
                    return Some(cell);
                }
                Lookup::Absent => return None,
                Lookup::MidEviction => Self::backoff(&mut spins),
            }
        }
    }

    fn lookup(&self, id: u64) -> Lookup {
        let mut shard = self.shard_for(id).lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(r) = shard.get_mut(&id) {
            r.last_touch = self.tick();
            return Lookup::Found(Arc::clone(&r.cell));
        }
        let Some(spill) = &self.spill else { return Lookup::Absent };
        if self.evicting.lock().unwrap_or_else(PoisonError::into_inner).contains(&id) {
            return Lookup::MidEviction;
        }
        // Not resident, not mid-eviction: restore from the sink if it is
        // there. Decoding under the shard lock serializes racing touches
        // of the same id — exactly one restore happens. Known trade-off:
        // other sessions on this shard stall for the decode (KRLS at
        // D=300 parses a ~MB document); acceptable at 16 shards, and a
        // `restoring` rendezvous (decode outside the lock, mirroring
        // `evict`) is the escape hatch if cold-restore tails ever matter.
        let text = match spill.sink.get(id) {
            Ok(Some(text)) => text,
            Ok(None) => return Lookup::Absent,
            Err(_) => {
                spill.stats.restore_failures.fetch_add(1, Ordering::Relaxed);
                return Lookup::Absent;
            }
        };
        match Self::decode(spill, &text) {
            Ok(session) => {
                let _ = spill.sink.delete(id);
                let cell = Arc::new(SessionSlot::new(session));
                let stamp = self.tick();
                shard.insert(id, Resident { cell: Arc::clone(&cell), last_touch: stamp });
                self.resident.fetch_add(1, Ordering::Relaxed);
                spill.stats.restores.fetch_add(1, Ordering::Relaxed);
                Lookup::Restored(cell)
            }
            Err(_) => {
                // snapshot stays in the sink for forensics; the touch
                // observes "no session"
                spill.stats.restore_failures.fetch_add(1, Ordering::Relaxed);
                Lookup::Absent
            }
        }
    }

    /// Remove the session under `id` and return it by value, restoring
    /// it from the spill sink when evicted.
    ///
    /// Router workers hold cell clones only for the duration of a single
    /// request, so after unlinking the id from its shard we wait until
    /// our `Arc` is the last reference, then unwrap it. The wait yields
    /// first and falls back to short sleeps, so a request still in flight
    /// on the session parks this thread briefly instead of burning a
    /// core. Crate-private: use
    /// [`crate::coordinator::CoordinatorService::remove_session`].
    pub(crate) fn remove(&self, id: u64) -> Option<FilterSession> {
        let mut spins = 0u32;
        loop {
            {
                let mut shard =
                    self.shard_for(id).lock().unwrap_or_else(PoisonError::into_inner);
                if let Some(r) = shard.remove(&id) {
                    drop(shard);
                    self.resident.fetch_sub(1, Ordering::Relaxed);
                    return Some(Self::unwrap_wait(r.cell));
                }
                let spill = self.spill.as_ref()?;
                if !self.evicting.lock().unwrap_or_else(PoisonError::into_inner).contains(&id)
                {
                    // settled: either spilled (restore and hand back) or
                    // truly absent
                    let text = match spill.sink.get(id) {
                        Ok(Some(text)) => text,
                        Ok(None) => return None,
                        Err(_) => {
                            spill.stats.restore_failures.fetch_add(1, Ordering::Relaxed);
                            return None;
                        }
                    };
                    return match Self::decode(spill, &text) {
                        Ok(session) => {
                            let _ = spill.sink.delete(id);
                            spill.stats.restores.fetch_add(1, Ordering::Relaxed);
                            Some(session)
                        }
                        Err(_) => {
                            spill.stats.restore_failures.fetch_add(1, Ordering::Relaxed);
                            None
                        }
                    };
                }
            }
            // mid-eviction: the spill completes shortly, then the sink has it
            Self::backoff(&mut spins);
        }
    }

    /// Serialized snapshot of session `id`, without disturbing residency:
    /// resident sessions serialize under their own lock (no LRU touch —
    /// reading a checkpoint is not "use"); spilled sessions return the
    /// sink's document directly instead of faulting megabytes of state
    /// resident (and evicting someone else) just to re-serialize it.
    pub fn snapshot_json(&self, id: u64) -> Option<String> {
        let mut spins = 0u32;
        loop {
            {
                let shard = self.shard_for(id).lock().unwrap_or_else(PoisonError::into_inner);
                if let Some(r) = shard.get(&id) {
                    let cell = Arc::clone(&r.cell);
                    drop(shard);
                    // shard lock released before the session lock, per the
                    // locking contract
                    let session = cell.lock();
                    return Some(session.snapshot().to_json());
                }
                let spill = self.spill.as_ref()?;
                if !self.evicting.lock().unwrap_or_else(PoisonError::into_inner).contains(&id)
                {
                    return spill.sink.get(id).ok().flatten();
                }
            }
            Self::backoff(&mut spins);
        }
    }

    /// Yield for the first attempts, then park briefly — the same
    /// escalation [`Self::unwrap_wait`] uses, shared by every
    /// mid-eviction retry loop so spinners never burn a core for the
    /// duration of a slow spill (an in-flight train + a disk write).
    fn backoff(spins: &mut u32) {
        *spins += 1;
        if *spins < 64 {
            std::thread::yield_now();
        } else {
            std::thread::sleep(std::time::Duration::from_micros(100));
        }
    }

    /// Wait until `cell` is the last reference, then unwrap the session.
    fn unwrap_wait(mut cell: SessionCell) -> FilterSession {
        let mut spins = 0u32;
        loop {
            match Arc::try_unwrap(cell) {
                Ok(slot) => return slot.into_session(),
                Err(still_shared) => {
                    cell = still_shared;
                    Self::backoff(&mut spins);
                }
            }
        }
    }

    /// Evict LRU sessions until the resident count honors the cap.
    /// Attempts are bounded so a touch storm (every candidate touched
    /// between selection and unlink) cannot wedge a worker here.
    fn enforce_cap(&self) {
        let Some(spill) = &self.spill else { return };
        for _ in 0..64 {
            if self.resident.load(Ordering::Relaxed) <= spill.max_resident {
                return;
            }
            let Some((id, stamp)) = self.lru_candidate() else { return };
            if !self.evict(spill, id, stamp) {
                return; // sink failure: stop evicting rather than spin
            }
        }
    }

    /// The resident session with the smallest touch stamp. A full scan,
    /// but of *resident* entries only — O(`max_resident`), not O(total
    /// sessions) — taking one shard lock at a time; per eviction this is
    /// microseconds against the snapshot serialize/parse that dominates
    /// every spill. Revisit with a stamp-ordered index only if profiles
    /// ever show otherwise.
    fn lru_candidate(&self) -> Option<(u64, u64)> {
        let mut best: Option<(u64, u64)> = None;
        for shard in &self.shards {
            let m = shard.lock().unwrap_or_else(PoisonError::into_inner);
            for (&id, r) in m.iter() {
                match best {
                    Some((_, t)) if r.last_touch >= t => {}
                    _ => best = Some((id, r.last_touch)),
                }
            }
        }
        best
    }

    /// Spill one session: unlink (iff untouched since selection), wait
    /// out in-flight borrowers so the snapshot holds every applied row,
    /// serialize, sink. Returns false only on a sink write failure (the
    /// session is re-admitted, not lost).
    fn evict(&self, spill: &SpillConfig, id: u64, stamp: u64) -> bool {
        let cell = {
            let mut shard = self.shard_for(id).lock().unwrap_or_else(PoisonError::into_inner);
            let untouched = matches!(shard.get(&id), Some(r) if r.last_touch == stamp);
            if !untouched {
                // touched or removed since selection — not idle after all
                return true;
            }
            // order: shard → eviction set (see module docs)
            self.evicting.lock().unwrap_or_else(PoisonError::into_inner).insert(id);
            shard.remove(&id).expect("present above").cell
        };
        self.resident.fetch_sub(1, Ordering::Relaxed);
        let session = Self::unwrap_wait(cell);
        let text = session.snapshot().to_json();
        // bounded-backoff retry: a transiently failing sink must not
        // force the re-admit path (which would immediately re-select
        // this same LRU victim and thrash)
        let ok = super::snapshot::put_with_retry(&*spill.sink, id, &text).is_ok();
        if ok {
            spill.stats.evictions.fetch_add(1, Ordering::Relaxed);
        } else {
            // a failing sink must not lose the session: re-admit it
            spill.stats.eviction_failures.fetch_add(1, Ordering::Relaxed);
            let stamp = self.tick();
            self.shard_for(id)
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .insert(id, Resident { cell: Arc::new(SessionSlot::new(session)), last_touch: stamp });
            self.resident.fetch_add(1, Ordering::Relaxed);
        }
        self.evicting.lock().unwrap_or_else(PoisonError::into_inner).remove(&id);
        ok
    }

    /// Currently resident (live) sessions.
    pub fn resident_count(&self) -> usize {
        self.resident.load(Ordering::Relaxed)
    }

    /// Sessions currently spilled to the sink.
    pub fn spilled_count(&self) -> usize {
        self.spill.as_ref().map_or(0, |s| s.sink.count())
    }

    /// Total number of live sessions — resident, spilled, and
    /// mid-eviction. Advisory under concurrent eviction: there is an
    /// instants-wide window (sink write landed, eviction-set entry not
    /// yet cleared) where one session can be counted in both tiers, so
    /// treat this as a monitoring number; exact counts come from
    /// quiescent states (every test asserts it that way).
    pub fn len(&self) -> usize {
        let resident: usize = self
            .shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(PoisonError::into_inner).len())
            .sum();
        let in_flight = if self.spill.is_some() {
            self.evicting.lock().unwrap_or_else(PoisonError::into_inner).len()
        } else {
            0
        };
        resident + in_flight + self.spilled_count()
    }

    /// True when no sessions are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::SessionConfig;
    use crate::coordinator::MemorySink;
    use crate::rng::run_rng;

    fn session(seed: u64) -> FilterSession {
        let mut rng = run_rng(seed, 0);
        FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap()
    }

    fn small_cfg() -> SessionConfig {
        SessionConfig { features: 16, ..SessionConfig::paper_default() }
    }

    fn spilled_store(max_resident: usize) -> (SessionStore, Arc<SpillStats>) {
        let stats = Arc::new(SpillStats::default());
        let store = SessionStore::with_spill(
            4,
            SpillConfig {
                max_resident,
                sink: Arc::new(MemorySink::new()),
                registry: Arc::new(MapRegistry::new()),
                executor: None,
                stats: Arc::clone(&stats),
            },
        );
        (store, stats)
    }

    #[test]
    fn shard_count_rounds_up_to_power_of_two() {
        assert_eq!(SessionStore::new(0).shard_count(), 1);
        assert_eq!(SessionStore::new(1).shard_count(), 1);
        assert_eq!(SessionStore::new(3).shard_count(), 4);
        assert_eq!(SessionStore::new(16).shard_count(), 16);
    }

    #[test]
    fn insert_get_remove_roundtrip() {
        let store = SessionStore::new(8);
        store.insert(7, session(1));
        assert_eq!(store.len(), 1);
        assert!(store.get(7).is_some());
        assert!(store.get(8).is_none());
        let s = store.remove(7).unwrap();
        assert_eq!(s.samples_seen(), 0);
        assert!(store.is_empty());
        assert!(store.remove(7).is_none());
    }

    #[test]
    fn sequential_ids_spread_across_shards() {
        let store = SessionStore::new(8);
        let hits: std::collections::BTreeSet<usize> =
            (0..16u64).map(|id| store.shard_index(id)).collect();
        assert!(hits.len() >= 4, "ids clumped onto {} shard(s)", hits.len());
        for id in 0..16u64 {
            assert!(store.shard_index(id) < store.shard_count());
        }
    }

    #[test]
    fn concurrent_trains_on_distinct_sessions_proceed() {
        use crate::signal::{NonlinearWiener, SignalSource};
        let store = Arc::new(SessionStore::new(8));
        for id in 0..8u64 {
            store.insert(id, session(100 + id));
        }
        let handles: Vec<_> = (0..8u64)
            .map(|id| {
                let store = Arc::clone(&store);
                std::thread::spawn(move || {
                    let cell = store.get(id).unwrap();
                    let mut src = NonlinearWiener::new(run_rng(id, 1), 0.05);
                    for smp in src.take_samples(200) {
                        cell.lock().train(&smp.x, smp.y).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for id in 0..8u64 {
            assert_eq!(store.remove(id).unwrap().samples_seen(), 200);
        }
    }

    #[test]
    fn remove_waits_out_transient_borrowers() {
        let store = Arc::new(SessionStore::new(4));
        store.insert(1, session(9));
        let cell = store.get(1).unwrap();
        let borrower = std::thread::spawn(move || {
            let guard = cell.lock();
            std::thread::sleep(std::time::Duration::from_millis(30));
            drop(guard);
            // `cell` drops here, releasing the last outside reference
        });
        // remove() spins until the borrower's clone is gone
        let s = store.remove(1).unwrap();
        assert_eq!(s.samples_seen(), 0);
        borrower.join().unwrap();
    }

    #[test]
    fn cap_evicts_lru_and_touch_restores() {
        use crate::signal::{NonlinearWiener, SignalSource};
        let (store, stats) = spilled_store(2);
        let mut rng = run_rng(50, 0);
        for id in 0..3u64 {
            store.insert(id, FilterSession::new(small_cfg(), &mut rng, None).unwrap());
        }
        // 3 inserted, cap 2: the LRU (id 0, inserted first) spilled
        assert_eq!(store.resident_count(), 2);
        assert_eq!(store.spilled_count(), 1);
        assert_eq!(store.len(), 3);
        assert_eq!(stats.evictions.load(Ordering::Relaxed), 1);
        assert!(store.get(1).is_some()); // resident hit, no restore
        assert_eq!(stats.restores.load(Ordering::Relaxed), 0);

        // train through an evict/restore cycle: touch id 0 → it restores
        // (and someone else spills)
        let mut src = NonlinearWiener::new(run_rng(50, 1), 0.05);
        let samples = src.take_samples(20);
        for smp in &samples {
            let cell = store.get(0).unwrap();
            cell.lock().train(&smp.x, smp.y).unwrap();
        }
        assert_eq!(stats.restores.load(Ordering::Relaxed), 1);
        assert_eq!(store.resident_count(), 2);
        assert_eq!(store.len(), 3);
        // the trained rows survived the spill round-trips
        let s0 = store.remove(0).unwrap();
        assert_eq!(s0.samples_seen(), 20);
        // removing spilled sessions restores them: in the end,
        // evictions == restores exactly
        assert!(store.remove(1).is_some());
        assert!(store.remove(2).is_some());
        assert!(store.is_empty());
        assert_eq!(
            stats.evictions.load(Ordering::Relaxed),
            stats.restores.load(Ordering::Relaxed)
        );
        assert_eq!(stats.restore_failures.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn eviction_prefers_least_recently_touched() {
        let (store, _) = spilled_store(2);
        let mut rng = run_rng(51, 0);
        store.insert(1, FilterSession::new(small_cfg(), &mut rng, None).unwrap());
        store.insert(2, FilterSession::new(small_cfg(), &mut rng, None).unwrap());
        // touch 1 so 2 becomes LRU, then overflow
        assert!(store.get(1).is_some());
        store.insert(3, FilterSession::new(small_cfg(), &mut rng, None).unwrap());
        assert_eq!(store.resident_count(), 2);
        // id 2 spilled; 1 and 3 resident — verify without get() (which
        // would restore): spilled_count is 1 and touching 1/3 causes no
        // restore
        assert_eq!(store.spilled_count(), 1);
        assert!(store.get(1).is_some());
        assert!(store.get(3).is_some());
        assert_eq!(store.spilled_count(), 1); // still only id 2 out
    }

    #[test]
    fn spilled_session_restores_evicting_another() {
        let (store, stats) = spilled_store(1);
        let mut rng = run_rng(52, 0);
        store.insert(1, FilterSession::new(small_cfg(), &mut rng, None).unwrap());
        store.insert(2, FilterSession::new(small_cfg(), &mut rng, None).unwrap());
        assert_eq!((store.resident_count(), store.spilled_count()), (1, 1));
        // touch the spilled one: it comes back, the other goes out
        assert!(store.get(1).is_some());
        assert_eq!((store.resident_count(), store.spilled_count()), (1, 1));
        assert_eq!(stats.restores.load(Ordering::Relaxed), 1);
        assert_eq!(stats.evictions.load(Ordering::Relaxed), 2);
        assert_eq!(store.len(), 2);
    }

    #[test]
    fn transient_sink_failures_do_not_fail_eviction() {
        // a sink that fails its first 2 puts recovers inside the spill
        // path's retry budget: the eviction lands (no re-admit thrash,
        // no eviction_failure), just with extra put attempts
        let sink = Arc::new(crate::daemon::fault::FlakySink::failing_puts(2));
        let stats = Arc::new(SpillStats::default());
        let store = SessionStore::with_spill(
            4,
            SpillConfig {
                max_resident: 1,
                sink: Arc::clone(&sink) as Arc<dyn SnapshotSink>,
                registry: Arc::new(MapRegistry::new()),
                executor: None,
                stats: Arc::clone(&stats),
            },
        );
        let mut rng = run_rng(53, 0);
        store.insert(1, FilterSession::new(small_cfg(), &mut rng, None).unwrap());
        store.insert(2, FilterSession::new(small_cfg(), &mut rng, None).unwrap());
        assert_eq!(stats.evictions.load(Ordering::Relaxed), 1);
        assert_eq!(stats.eviction_failures.load(Ordering::Relaxed), 0);
        assert_eq!((store.resident_count(), store.spilled_count()), (1, 1));
        assert_eq!(sink.put_attempts(), 3, "two injected failures + one success");
        // the spilled session is intact behind the flaky sink
        assert!(store.get(1).is_some());
    }

    #[test]
    fn persistent_sink_failure_readmits_session() {
        // a sink that never recovers exhausts the retry budget: the
        // session must be re-admitted (never lost) and the incident
        // counted as an eviction_failure
        let sink = Arc::new(crate::daemon::fault::FlakySink::failing_puts(u64::MAX));
        let stats = Arc::new(SpillStats::default());
        let store = SessionStore::with_spill(
            4,
            SpillConfig {
                max_resident: 1,
                sink: Arc::clone(&sink) as Arc<dyn SnapshotSink>,
                registry: Arc::new(MapRegistry::new()),
                executor: None,
                stats: Arc::clone(&stats),
            },
        );
        let mut rng = run_rng(54, 0);
        store.insert(1, FilterSession::new(small_cfg(), &mut rng, None).unwrap());
        store.insert(2, FilterSession::new(small_cfg(), &mut rng, None).unwrap());
        assert_eq!(stats.evictions.load(Ordering::Relaxed), 0);
        assert!(stats.eviction_failures.load(Ordering::Relaxed) >= 1);
        assert_eq!(store.spilled_count(), 0);
        assert_eq!(store.len(), 2, "failed eviction must not lose the session");
        assert!(store.get(1).is_some() && store.get(2).is_some());
    }

    #[test]
    fn no_spill_means_unbounded_residency() {
        let store = SessionStore::new(2);
        for id in 0..16u64 {
            store.insert(id, session(id));
        }
        assert_eq!(store.len(), 16);
        assert_eq!(store.resident_count(), 16);
        assert_eq!(store.spilled_count(), 0);
    }
}
