//! Sharded, per-session-locked session store — the concurrency substrate
//! of the coordinator service.
//!
//! The paper's fixed-size-θ property means every session is a small,
//! self-contained `(θ, Ω, b)` state with O(D) updates; nothing about one
//! session's train touches another's. The store mirrors that in the lock
//! structure: session ids hash onto `N` shards, each shard is a
//! `Mutex<BTreeMap<u64, Arc<Mutex<FilterSession>>>>`, and all mutation of
//! a session happens under that session's *own* mutex.
//!
//! Locking contract (also documented on [`crate::coordinator`]):
//!
//! * **Shard locks** are held only for map operations — insert, remove,
//!   id lookup, len. Never while training, predicting or dispatching.
//! * **Session locks** are held for exactly one train/flush call, or just
//!   long enough to snapshot predict state ([`super::session::PredictState`]).
//!   No predict — PJRT batch or native per-row — runs under any lock;
//!   only a session's own train (which on the PJRT backend may dispatch
//!   a chunk) holds that session's lock.
//! * Lock order is always shard → session; no path ever takes two shard
//!   locks or two session locks at once, so deadlock is impossible.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, PoisonError};

use super::session::FilterSession;

/// A shared, mutably-lockable session slot handed out by the store.
/// Crate-private: see [`SessionStore::get`] for why cells never escape.
pub(crate) type SessionCell = Arc<Mutex<FilterSession>>;

type Shard = Mutex<BTreeMap<u64, SessionCell>>;

/// Sharded map from session id to independently locked [`FilterSession`].
pub struct SessionStore {
    shards: Vec<Shard>,
    /// `shards.len() - 1`; the shard count is a power of two so the
    /// hash→shard reduction is a mask, not a modulo.
    mask: u64,
}

impl SessionStore {
    /// Store with at least `shards` shards (rounded up to a power of two,
    /// minimum 1).
    pub fn new(shards: usize) -> Self {
        let n = shards.max(1).next_power_of_two();
        Self {
            shards: (0..n).map(|_| Mutex::new(BTreeMap::new())).collect(),
            mask: (n - 1) as u64,
        }
    }

    /// Number of shards (power of two).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Shard index for `id`. Session ids are sequential, so spread them
    /// with a Fibonacci hash before masking — consecutive ids land on
    /// different shards. Public for diagnostics and so tests exercise
    /// the real hash rather than a reimplementation.
    pub fn shard_index(&self, id: u64) -> usize {
        let h = id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        ((h >> 32) & self.mask) as usize
    }

    fn shard_for(&self, id: u64) -> &Shard {
        &self.shards[self.shard_index(id)]
    }

    /// Insert `session` under `id` (replacing any previous occupant).
    /// Crate-private: ids are allocated by `CoordinatorService`'s counter;
    /// outside inserts could silently clobber a live session.
    pub(crate) fn insert(&self, id: u64, session: FilterSession) {
        self.shard_for(id)
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .insert(id, Arc::new(Mutex::new(session)));
    }

    /// Clone the session cell for `id`. Callers lock the returned cell to
    /// train/flush or snapshot; the shard lock is released before this
    /// function returns.
    ///
    /// Crate-private on purpose: a caller that retained a cell while also
    /// calling [`SessionStore::remove`] on the same thread would deadlock
    /// that removal (it waits for the last outside reference to drop), so
    /// cells never leave the crate — router workers hold one per request.
    pub(crate) fn get(&self, id: u64) -> Option<SessionCell> {
        self.shard_for(id).lock().unwrap_or_else(PoisonError::into_inner).get(&id).cloned()
    }

    /// Remove the session under `id` and return it by value.
    ///
    /// Router workers hold cell clones only for the duration of a single
    /// request, so after unlinking the id from its shard we wait until
    /// our `Arc` is the last reference, then unwrap it. The wait yields
    /// first and falls back to short sleeps, so a request still in flight
    /// on the session parks this thread briefly instead of burning a
    /// core. Workers drop their cell clone at the end of each request, so
    /// the wait is bounded by one train/flush/snapshot. Crate-private:
    /// use [`crate::coordinator::CoordinatorService::remove_session`].
    pub(crate) fn remove(&self, id: u64) -> Option<FilterSession> {
        let mut cell =
            self.shard_for(id).lock().unwrap_or_else(PoisonError::into_inner).remove(&id)?;
        let mut spins = 0u32;
        loop {
            match Arc::try_unwrap(cell) {
                Ok(m) => return Some(m.into_inner().unwrap_or_else(PoisonError::into_inner)),
                Err(still_shared) => {
                    cell = still_shared;
                    spins += 1;
                    if spins < 64 {
                        std::thread::yield_now();
                    } else {
                        std::thread::sleep(std::time::Duration::from_micros(100));
                    }
                }
            }
        }
    }

    /// Total number of live sessions (sums shard lengths; takes each
    /// shard lock in turn, never two at once).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(PoisonError::into_inner).len())
            .sum()
    }

    /// True when no sessions are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::session::SessionConfig;
    use crate::rng::run_rng;

    fn session(seed: u64) -> FilterSession {
        let mut rng = run_rng(seed, 0);
        FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap()
    }

    #[test]
    fn shard_count_rounds_up_to_power_of_two() {
        assert_eq!(SessionStore::new(0).shard_count(), 1);
        assert_eq!(SessionStore::new(1).shard_count(), 1);
        assert_eq!(SessionStore::new(3).shard_count(), 4);
        assert_eq!(SessionStore::new(16).shard_count(), 16);
    }

    #[test]
    fn insert_get_remove_roundtrip() {
        let store = SessionStore::new(8);
        store.insert(7, session(1));
        assert_eq!(store.len(), 1);
        assert!(store.get(7).is_some());
        assert!(store.get(8).is_none());
        let s = store.remove(7).unwrap();
        assert_eq!(s.samples_seen(), 0);
        assert!(store.is_empty());
        assert!(store.remove(7).is_none());
    }

    #[test]
    fn sequential_ids_spread_across_shards() {
        let store = SessionStore::new(8);
        let hits: std::collections::BTreeSet<usize> =
            (0..16u64).map(|id| store.shard_index(id)).collect();
        assert!(hits.len() >= 4, "ids clumped onto {} shard(s)", hits.len());
        for id in 0..16u64 {
            assert!(store.shard_index(id) < store.shard_count());
        }
    }

    #[test]
    fn concurrent_trains_on_distinct_sessions_proceed() {
        use crate::signal::{NonlinearWiener, SignalSource};
        let store = Arc::new(SessionStore::new(8));
        for id in 0..8u64 {
            store.insert(id, session(100 + id));
        }
        let handles: Vec<_> = (0..8u64)
            .map(|id| {
                let store = Arc::clone(&store);
                std::thread::spawn(move || {
                    let cell = store.get(id).unwrap();
                    let mut src = NonlinearWiener::new(run_rng(id, 1), 0.05);
                    for smp in src.take_samples(200) {
                        cell.lock().unwrap().train(&smp.x, smp.y).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for id in 0..8u64 {
            assert_eq!(store.remove(id).unwrap().samples_seen(), 200);
        }
    }

    #[test]
    fn remove_waits_out_transient_borrowers() {
        let store = Arc::new(SessionStore::new(4));
        store.insert(1, session(9));
        let cell = store.get(1).unwrap();
        let borrower = std::thread::spawn(move || {
            let guard = cell.lock().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(30));
            drop(guard);
            // `cell` drops here, releasing the last outside reference
        });
        // remove() spins until the borrower's clone is gone
        let s = store.remove(1).unwrap();
        assert_eq!(s.samples_seen(), 0);
        borrower.join().unwrap();
    }
}
