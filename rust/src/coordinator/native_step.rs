//! The shared native step kernels for **f32-state** sessions — the
//! single place where the PJRT sessions' "matching math" lives.
//!
//! PJRT-backed [`FilterSession`](super::FilterSession)s hold f32 state
//! (θ, P) because that is what the AOT artifacts compute in. When a
//! partially-filled chunk must finish natively (`flush()`), the remainder
//! rows have to be stepped with *exactly* the mixed-precision recipe the
//! artifacts use — f64 features and accumulation, f32 state read/write —
//! or the native remainder would drift from the device path. These
//! kernels are that recipe, extracted so no call site hand-inlines it:
//! `flush()` loops them per remainder row, and the session/integration
//! tests bound them against both the f64 filters and the artifacts.
//!
//! The f64 (native-backend) hot path does **not** live here — it is the
//! [`OnlineRegressor`](crate::kaf::OnlineRegressor) step/train_batch
//! family in `kaf/`.
//!
//! These kernels are map-kind agnostic — features come from
//! [`FeatureMap::apply_into`], whose evaluation contract carries the
//! quadrature per-feature weights internally — but in practice only
//! static-RFF maps flow through them:
//! [`FilterSession::build`](super::FilterSession) pins the PJRT backend
//! (the only caller, via `flush()`) to [`MapKind::StaticRff`] because
//! the AOT artifacts bake the uniform-weight feature recipe. Quadrature
//! and adaptive sessions run the native f64 path instead, and the
//! adaptive Ω update lives in [`RffKlms::step`](crate::kaf::RffKlms),
//! never in this chunk-remainder path.
//!
//! [`FeatureMap::apply_into`]: crate::kaf::FeatureMap::apply_into
//! [`MapKind::StaticRff`]: crate::kaf::MapKind

use crate::kaf::RffMap;
use crate::linalg::simd;

/// One RFF-KLMS step on f32 state: `ŷ = θᵀz`, `e = y − ŷ`,
/// `θ ← θ + μ e z` with f64 feature/error math and per-element f32
/// rounding on the θ write-back (the artifact's precision profile).
/// The feature map and both vector sweeps run on the lane substrate
/// ([`simd::dot_f64_f32`], [`simd::axpy_into_f32`]) — the same vector
/// code path as the f64 filters. `z` is a reusable length-D scratch;
/// returns the a-priori error.
pub(crate) fn klms_step(
    map: &RffMap,
    theta: &mut [f32],
    mu: f32,
    x: &[f64],
    y: f32,
    z: &mut [f64],
) -> f64 {
    debug_assert_eq!(theta.len(), map.features());
    map.apply_into(x, z);
    let yhat = simd::dot_f64_f32(z, theta);
    let e = y as f64 - yhat;
    simd::axpy_into_f32(mu as f64 * e, z, theta);
    e
}

/// One RFF-KRLS step on f32 state (`P` row-major `[D, D]` — the device
/// artifact's dense layout, unlike the native filter's packed
/// triangle): the RLS recursion `π = Pz`, `denom = β + zᵀπ`,
/// `θ ← θ + π e/denom`, `P ← (P − π πᵀ/denom)/β`, all in f64 with f32
/// rounding on the θ/P write-backs, every sweep on the lane substrate
/// ([`simd::dot_f32_f64`] row sweeps, [`simd::scale_rank1_row_f32`]
/// rank-1 rows). `z`/`pi` are reusable length-D scratches; returns the
/// a-priori error.
#[allow(clippy::too_many_arguments)]
pub(crate) fn krls_step(
    map: &RffMap,
    theta: &mut [f32],
    p: &mut [f32],
    beta: f32,
    x: &[f64],
    y: f32,
    z: &mut [f64],
    pi: &mut [f64],
) -> f64 {
    let features = theta.len();
    debug_assert_eq!(features, map.features());
    debug_assert_eq!(p.len(), features * features);
    map.apply_into(x, z);
    for (i, pi_i) in pi.iter_mut().enumerate() {
        *pi_i = simd::dot_f32_f64(&p[i * features..(i + 1) * features], z);
    }
    let denom = beta as f64 + simd::dot(pi, z);
    let yhat = simd::dot_f64_f32(z, theta);
    let e = y as f64 - yhat;
    let esc = e / denom;
    simd::axpy_into_f32(esc, pi, theta);
    let inv_beta = 1.0 / beta as f64;
    let c = inv_beta / denom;
    for i in 0..features {
        let cpi = c * pi[i];
        simd::scale_rank1_row_f32(&mut p[i * features..(i + 1) * features], inv_beta, cpi, pi);
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kaf::kernels::Kernel;
    use crate::kaf::{OnlineRegressor, RffKlms, RffKrls};
    use crate::rng::run_rng;
    use crate::signal::{NonlinearWiener, SignalSource};

    #[test]
    fn f32_klms_tracks_f64_filter() {
        // the f32 kernel is the f64 step with rounding on the state
        // write-back: errors must track within f32 resolution over a run
        let mut rng = run_rng(1, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 100);
        let mut f64_filter = RffKlms::new(map.clone(), 1.0);
        let mut theta = vec![0.0f32; 100];
        let mut z = vec![0.0f64; 100];
        let mut src = NonlinearWiener::new(run_rng(1, 1), 0.05);
        let mut max_div = 0.0f64;
        for s in src.take_samples(300) {
            let e64 = f64_filter.step(&s.x, s.y);
            let e32 = klms_step(&map, &mut theta, 1.0, &s.x, s.y as f32, &mut z);
            max_div = max_div.max((e64 - e32).abs());
        }
        assert!(max_div < 1e-3, "f32 kernel diverged from f64 filter: {max_div}");
    }

    #[test]
    fn f32_krls_tracks_f64_filter() {
        let mut rng = run_rng(2, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 60);
        let (beta, lambda) = (0.9995f64, 1e-2f64);
        let mut f64_filter = RffKrls::new(map.clone(), beta, lambda);
        let mut theta = vec![0.0f32; 60];
        let mut p = vec![0.0f32; 60 * 60];
        for i in 0..60 {
            p[i * 60 + i] = (1.0 / lambda) as f32;
        }
        let (mut z, mut pi) = (vec![0.0f64; 60], vec![0.0f64; 60]);
        let mut src = NonlinearWiener::new(run_rng(2, 1), 0.05);
        let mut max_div = 0.0f64;
        for s in src.take_samples(200) {
            let e64 = f64_filter.step(&s.x, s.y);
            let e32 = krls_step(
                &map,
                &mut theta,
                &mut p,
                beta as f32,
                &s.x,
                s.y as f32,
                &mut z,
                &mut pi,
            );
            max_div = max_div.max((e64 - e32).abs());
        }
        assert!(max_div < 5e-2, "f32 kernel diverged from f64 filter: {max_div}");
    }
}
