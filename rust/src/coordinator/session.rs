//! Filter sessions: one online-learning state machine per stream.
//!
//! A session is configured with an algorithm + kernel + feature map and a
//! backend:
//! * [`Backend::Native`] — pure-Rust per-sample updates (lowest latency).
//! * [`Backend::Pjrt`] — samples buffered into N-sample chunks executed
//!   by the AOT artifact via the [`ExecutorHandle`]; remainders at
//!   `flush()` run natively with matching math (f32 state, f64 features;
//!   the integration tests bound the difference against the artifact).

use std::sync::Arc;

use anyhow::Result;

use crate::distributed::{DiffusionAlgo, DiffusionNetwork, DiffusionOrdering, NetworkTopology};
use crate::kaf::checkpoint::MapPayload;
use crate::kaf::kernels::Kernel;
use crate::kaf::{
    MapKind, MapRegistry, MapSpec, OnlineRegressor, RffKlms, RffKrls, RffMap, RffNlms,
};
use crate::rng::Rng;
use crate::runtime::ExecutorHandle;

use super::native_step;
use super::snapshot::{SessionSnapshot, SnapshotState};

/// Which algorithm a session runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Algo {
    /// RFF-KLMS with step size μ.
    RffKlms {
        /// LMS step size.
        mu: f64,
    },
    /// RFF-KRLS with forgetting β and regularization λ.
    RffKrls {
        /// Forgetting factor.
        beta: f64,
        /// Regularization (P₀ = I/λ).
        lambda: f64,
    },
    /// RFF-NLMS with step size μ and normalization regularizer ε
    /// (native backend only — there is no NLMS AOT artifact).
    RffNlms {
        /// NLMS step size (μ ∈ (0, 2) for stability).
        mu: f64,
        /// Normalization regularizer.
        eps: f64,
    },
}

/// Execution backend for a session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust per-sample hot path.
    Native,
    /// Chunked AOT execution through PJRT.
    Pjrt,
}

/// Session configuration.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Input dimension d.
    pub dim: usize,
    /// Feature count D.
    pub features: usize,
    /// Kernel (bandwidth matters: frequencies are drawn from its
    /// spectral density).
    pub kernel: Kernel,
    /// Algorithm + hyperparameters.
    pub algo: Algo,
    /// Backend selection.
    pub backend: Backend,
}

impl SessionConfig {
    /// The paper's Ex.-2 serving config: d=5, D=300, σ=5, RFF-KLMS μ=1.
    pub fn paper_default() -> Self {
        Self {
            dim: 5,
            features: 300,
            kernel: Kernel::Gaussian { sigma: 5.0 },
            algo: Algo::RffKlms { mu: 1.0 },
            backend: Backend::Native,
        }
    }
}

/// Configuration of a diffusion group session: the per-node filter
/// config plus the network structure. The group trains through
/// [`Request::TrainDiffusion`](super::Request::TrainDiffusion) on
/// row-major `[rounds · nodes, dim]` windows and is snapshot/spilled
/// through the same machinery as every other session.
#[derive(Clone, Debug)]
pub struct DiffusionGroupConfig {
    /// Per-node dim/features/kernel/algo. The backend must be
    /// [`Backend::Native`], and the algo [`Algo::RffKlms`] or
    /// [`Algo::RffNlms`] (diffusion combines θ only — KRLS's P is
    /// per-node second-order state the scheme does not exchange).
    pub session: SessionConfig,
    /// Which half-step runs first in a round.
    pub ordering: DiffusionOrdering,
    /// The undirected network the nodes diffuse over.
    pub topology: NetworkTopology,
}

impl DiffusionGroupConfig {
    /// Map the session algo onto a diffusion adapt rule, rejecting the
    /// combinations a group cannot run.
    fn diffusion_algo(&self) -> Result<DiffusionAlgo> {
        anyhow::ensure!(
            self.session.backend == Backend::Native,
            "diffusion groups run on the native backend"
        );
        match self.session.algo {
            Algo::RffKlms { mu } => Ok(DiffusionAlgo::Klms { mu }),
            Algo::RffNlms { mu, eps } => Ok(DiffusionAlgo::Nlms { mu, eps }),
            Algo::RffKrls { .. } => anyhow::bail!(
                "diffusion groups support the KLMS/NLMS adapt rules \
                 (per-node P is not exchangeable network state)"
            ),
        }
    }
}

enum SessionState {
    NativeKlms(RffKlms),
    NativeKrls(RffKrls),
    NativeNlms(RffNlms),
    /// A whole diffusion network served as one session: per-node θ over
    /// one shared map, trained in rounds via `train_diffusion`.
    Diffusion(DiffusionNetwork),
    // PJRT variants hold only the f32 *learned* state and chunk buffers;
    // the f32 (Ω, b) staging tensors live in the shared map's cached
    // `f32_view()` — one copy per map, not per session.
    PjrtKlms {
        map: Arc<RffMap>,
        theta: Vec<f32>,
        mu: f32,
        buf_x: Vec<f32>,
        buf_y: Vec<f32>,
        chunk_n: usize,
    },
    PjrtKrls {
        map: Arc<RffMap>,
        theta: Vec<f32>,
        p: Vec<f32>,
        beta: f32,
        buf_x: Vec<f32>,
        buf_y: Vec<f32>,
        chunk_n: usize,
    },
}

/// An immutable snapshot of everything a prediction needs: the frozen
/// feature map `(Ω, b)` plus the weight vector θ at snapshot time.
///
/// The service's dynamic batcher takes one of these under the session
/// lock and releases the lock *before* any PJRT dispatch or native
/// per-row predict runs — predictions are then lock-free and trains on
/// the same session proceed concurrently. Taking the snapshot is one
/// `Arc` bump for the map plus a θ copy (2.4 KB at D=300) — far cheaper
/// than holding a lock across a device round-trip.
#[derive(Clone, Debug)]
pub struct PredictState {
    map: Arc<RffMap>,
    theta: Vec<f64>,
}

impl PredictState {
    /// Input dimension d.
    pub fn dim(&self) -> usize {
        self.map.dim()
    }

    /// Feature count D.
    pub fn features(&self) -> usize {
        self.map.features()
    }

    /// The frozen feature map.
    pub fn map(&self) -> &RffMap {
        &self.map
    }

    /// Weight vector θ at snapshot time.
    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    /// θ as f32 (the PJRT artifact input layout).
    pub fn theta_f32(&self) -> Vec<f32> {
        self.theta.iter().map(|&v| v as f32).collect()
    }

    /// `ŷ = θᵀ z_Ω(x)` — the Z-free fused kernel with n = 1: no feature
    /// store and **no allocation** (the router's per-row fallback calls
    /// this in a loop, so a per-call `Vec` would be steady-state churn).
    /// Single-accumulator order — bitwise identical to
    /// [`Self::predict_batch`] and [`FilterSession::predict`].
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut out = [0.0];
        self.map.predict_batch_into(x, &self.theta, &mut out);
        out[0]
    }

    /// Batched predict over row-major `[n, dim]` probes, writing `n`
    /// predictions into `out`. Runs the blocked **Z-free** fused kernel
    /// ([`RffMap::predict_batch_into`](crate::kaf::FeatureMap::predict_batch_into)) — no feature matrix stored, no
    /// allocation (the caller owns `out`), bitwise the same values as
    /// per-row [`Self::predict`]. The service's native fallback serves
    /// whole bursts through this with one reused `out` buffer per router
    /// worker.
    pub fn predict_batch(&self, xs: &[f64], out: &mut [f64]) {
        self.map.predict_batch_into(xs, &self.theta, out);
    }
}

/// One streaming filter session.
///
/// The frozen `(Ω, b)` lives behind **one** `Arc<RffMap>` held by the
/// filter (or the PJRT state) — the same handle [`Self::predict_state`]
/// bumps and, for interned maps, the same allocation every other
/// same-spec session in the fleet shares. A session's own state is just
/// θ (and P / chunk buffers): the paper's fixed-size property, resident.
pub struct FilterSession {
    config: SessionConfig,
    state: SessionState,
    executor: Option<ExecutorHandle>,
    samples_seen: usize,
    sum_sq_err: f64,
    /// Registry identity of the map when known (sessions built by
    /// [`Self::from_spec`] or restored from a reference snapshot). Lets
    /// [`Self::snapshot`] serialize the map as a spec instead of by
    /// value, so a fleet snapshot stores Ω once.
    map_spec: Option<MapSpec>,
}

impl FilterSession {
    /// Create a session, drawing the feature map from `rng`.
    /// `executor` is required for [`Backend::Pjrt`].
    pub fn new(
        config: SessionConfig,
        rng: &mut Rng,
        executor: Option<ExecutorHandle>,
    ) -> Result<Self> {
        let map = RffMap::draw(rng, config.kernel, config.dim, config.features);
        Self::with_map(config, map, executor)
    }

    /// Create a session with an explicit feature map — owned, or an
    /// `Arc` already shared with other sessions (tests share `(Ω, b)`
    /// between native and PJRT sessions this way).
    pub fn with_map(
        config: SessionConfig,
        map: impl Into<Arc<RffMap>>,
        executor: Option<ExecutorHandle>,
    ) -> Result<Self> {
        Self::build(config, map.into(), None, executor)
    }

    /// Create a session whose map is **interned**: the spec
    /// `(config.kernel, dim, features, seed)` resolves through `registry`,
    /// so every same-spec session shares one resident `(Ω, b)` and this
    /// session's snapshots carry a map reference instead of the arrays.
    pub fn from_spec(
        config: SessionConfig,
        seed: u64,
        registry: &MapRegistry,
        executor: Option<ExecutorHandle>,
    ) -> Result<Self> {
        let spec = MapSpec::new(config.kernel, config.dim, config.features, seed);
        let map = registry.get_or_draw(&spec);
        Self::build(config, map, Some(spec), executor)
    }

    /// Create a session from an explicit [`MapSpec`] — the fully general
    /// interned constructor: any map kind ([`MapSpec::new`],
    /// [`MapSpec::quadrature`], [`MapSpec::adaptive`]) resolves through
    /// `registry`. Adaptive sessions share the interned *initial* draw
    /// until their first Ω update clones a private map (copy-on-adapt),
    /// and their snapshots always carry Ω inline.
    pub fn from_map_spec(
        config: SessionConfig,
        spec: MapSpec,
        registry: &MapRegistry,
        executor: Option<ExecutorHandle>,
    ) -> Result<Self> {
        anyhow::ensure!(
            spec.kernel == config.kernel
                && spec.dim == config.dim
                && spec.features == config.features,
            "map spec (kernel {:?}, d={}, D={}) does not match session config \
             (kernel {:?}, d={}, D={})",
            spec.kernel,
            spec.dim,
            spec.features,
            config.kernel,
            config.dim,
            config.features
        );
        let map = registry.get_or_draw(&spec);
        Self::build(config, map, Some(spec), executor)
    }

    /// Create a diffusion group session with an explicit shared map —
    /// owned, or an `Arc` already interned elsewhere.
    pub fn diffusion_with_map(
        config: DiffusionGroupConfig,
        map: impl Into<Arc<RffMap>>,
    ) -> Result<Self> {
        Self::build_diffusion(config, map.into(), None)
    }

    /// Create a diffusion group whose shared map is **interned**: the
    /// whole group — every node — and every other same-spec session in
    /// the fleet resolve to one resident `(Ω, b)`; the group's snapshots
    /// carry a map reference instead of the arrays. This is the paper's
    /// "agreeing on a map costs one seed exchange" point, fleet-wide.
    pub fn diffusion_from_spec(
        config: DiffusionGroupConfig,
        seed: u64,
        registry: &MapRegistry,
    ) -> Result<Self> {
        let spec = MapSpec::new(
            config.session.kernel,
            config.session.dim,
            config.session.features,
            seed,
        );
        let map = registry.get_or_draw(&spec);
        Self::build_diffusion(config, map, Some(spec))
    }

    fn build_diffusion(
        config: DiffusionGroupConfig,
        map: Arc<RffMap>,
        map_spec: Option<MapSpec>,
    ) -> Result<Self> {
        anyhow::ensure!(
            map.dim() == config.session.dim && map.features() == config.session.features,
            "map shape (d={}, D={}) does not match config (d={}, D={})",
            map.dim(),
            map.features(),
            config.session.dim,
            config.session.features
        );
        anyhow::ensure!(
            !map.kind().is_adaptive(),
            "diffusion groups require a frozen map kind (got {}): every node \
             shares one (Ω, b) and exchanges θ only",
            map.kind().name()
        );
        let algo = config.diffusion_algo()?;
        let net = DiffusionNetwork::new(config.topology, map, algo, config.ordering);
        Ok(Self {
            config: config.session,
            state: SessionState::Diffusion(net),
            executor: None,
            samples_seen: 0,
            sum_sq_err: 0.0,
            map_spec,
        })
    }

    fn build(
        config: SessionConfig,
        map: Arc<RffMap>,
        map_spec: Option<MapSpec>,
        executor: Option<ExecutorHandle>,
    ) -> Result<Self> {
        anyhow::ensure!(
            map.dim() == config.dim && map.features() == config.features,
            "map shape (d={}, D={}) does not match config (d={}, D={})",
            map.dim(),
            map.features(),
            config.dim,
            config.features
        );
        // map-kind gates: the PJRT artifacts stage one frozen f32 (Ω, b)
        // with a uniform scale, so only static-RFF maps run there; the
        // adaptive Ω gradient lives in RffKlms::step, so it needs the
        // native KLMS state.
        if config.backend == Backend::Pjrt {
            anyhow::ensure!(
                map.kind() == MapKind::StaticRff,
                "the PJRT backend requires a static RFF map, got '{}'",
                map.kind().name()
            );
        }
        if map.kind().is_adaptive() {
            anyhow::ensure!(
                matches!(config.algo, Algo::RffKlms { .. }),
                "adaptive-RFF maps run the ARFF-GKLMS rule, which only \
                 RFF-KLMS implements (got {:?})",
                config.algo
            );
        }
        let state = match (config.backend, config.algo) {
            (Backend::Native, Algo::RffKlms { mu }) => {
                SessionState::NativeKlms(RffKlms::new(map, mu))
            }
            (Backend::Native, Algo::RffKrls { beta, lambda }) => {
                SessionState::NativeKrls(RffKrls::new(map, beta, lambda))
            }
            (Backend::Native, Algo::RffNlms { mu, eps }) => {
                SessionState::NativeNlms(RffNlms::new(map, mu, eps))
            }
            (Backend::Pjrt, algo) => {
                let handle = executor
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("PJRT backend requires an executor"))?;
                let kind = match algo {
                    Algo::RffKlms { .. } => "rffklms_chunk",
                    Algo::RffKrls { .. } => "rffkrls_chunk",
                    Algo::RffNlms { .. } => {
                        anyhow::bail!("RFF-NLMS has no AOT artifact; use the native backend")
                    }
                };
                let chunk_n = handle.chunk_len(kind, config.dim, config.features)?;
                match algo {
                    Algo::RffKlms { mu } => SessionState::PjrtKlms {
                        theta: vec![0.0; config.features],
                        mu: mu as f32,
                        buf_x: Vec::with_capacity(chunk_n * config.dim),
                        buf_y: Vec::with_capacity(chunk_n),
                        chunk_n,
                        map,
                    },
                    Algo::RffKrls { beta, lambda } => {
                        let mut p = vec![0.0f32; config.features * config.features];
                        for i in 0..config.features {
                            p[i * config.features + i] = 1.0 / lambda as f32;
                        }
                        SessionState::PjrtKrls {
                            theta: vec![0.0; config.features],
                            p,
                            beta: beta as f32,
                            buf_x: Vec::with_capacity(chunk_n * config.dim),
                            buf_y: Vec::with_capacity(chunk_n),
                            chunk_n,
                            map,
                        }
                    }
                    Algo::RffNlms { .. } => unreachable!("rejected by the kind match above"),
                }
            }
        };
        Ok(Self { config, state, executor, samples_seen: 0, sum_sq_err: 0.0, map_spec })
    }

    /// Session configuration.
    pub fn config(&self) -> &SessionConfig {
        &self.config
    }

    /// Rows whose update has actually been **applied**: native rows
    /// immediately, PJRT rows once their chunk dispatched successfully
    /// (or `flush()` ran the remainder natively). Buffered-but-undispatched
    /// rows and rows lost to a failed dispatch are *not* counted, so this
    /// always agrees with the errors folded into [`Self::running_mse`].
    pub fn samples_seen(&self) -> usize {
        self.samples_seen
    }

    /// Running MSE over everything ingested (a-priori errors).
    pub fn running_mse(&self) -> f64 {
        if self.samples_seen == 0 {
            0.0
        } else {
            self.sum_sq_err / self.samples_seen as f64
        }
    }

    /// The feature map.
    pub fn map(&self) -> &RffMap {
        self.map_arc()
    }

    /// The shared map handle — the *only* resident copy of `(Ω, b)` this
    /// session holds. `Arc::strong_count` on it counts the whole fleet's
    /// sharing (plus the registry's own reference for interned maps).
    pub fn map_arc(&self) -> &Arc<RffMap> {
        match &self.state {
            SessionState::NativeKlms(f) => f.map_arc(),
            SessionState::NativeKrls(f) => f.map_arc(),
            SessionState::NativeNlms(f) => f.map_arc(),
            SessionState::Diffusion(net) => net.map_arc(),
            SessionState::PjrtKlms { map, .. } | SessionState::PjrtKrls { map, .. } => map,
        }
    }

    /// The diffusion network, when this session is a group
    /// (`None` for single-filter sessions).
    pub fn diffusion(&self) -> Option<&DiffusionNetwork> {
        match &self.state {
            SessionState::Diffusion(net) => Some(net),
            _ => None,
        }
    }

    /// The registry identity of the map, when this session was built
    /// from one ([`Self::from_spec`] or a reference-snapshot restore).
    pub fn map_spec(&self) -> Option<MapSpec> {
        self.map_spec
    }

    /// Current weight vector θ (f64 view). For a diffusion group this is
    /// the **network-mean** θ — the consensus estimate the group serves
    /// predictions from; per-node weights are on
    /// [`DiffusionNetwork::theta`] via [`Self::diffusion`].
    pub fn theta(&self) -> Vec<f64> {
        match &self.state {
            SessionState::NativeKlms(f) => f.theta().to_vec(),
            SessionState::NativeKrls(f) => f.theta().to_vec(),
            SessionState::NativeNlms(f) => f.theta().to_vec(),
            SessionState::Diffusion(net) => net.theta_mean(),
            SessionState::PjrtKlms { theta, .. } | SessionState::PjrtKrls { theta, .. } => {
                theta.iter().map(|&v| v as f64).collect()
            }
        }
    }

    /// Snapshot the predict-relevant state `(Ω, b, θ)` — see
    /// [`PredictState`]. Cheap: one `Arc` bump for the frozen map + one
    /// θ copy, no device traffic. Callers (the service batcher) drop the
    /// session lock right after taking this.
    pub fn predict_state(&self) -> PredictState {
        PredictState { map: Arc::clone(self.map_arc()), theta: self.theta() }
    }

    /// Predict `ŷ(x)` with the current model. Single-sample predicts use
    /// the native map even on PJRT sessions (one dispatch per scalar is
    /// never worth it; batched predicts go through the service batcher).
    pub fn predict(&self, x: &[f64]) -> f64 {
        match &self.state {
            SessionState::NativeKlms(f) => f.predict(x),
            SessionState::NativeKrls(f) => f.predict(x),
            SessionState::NativeNlms(f) => f.predict(x),
            SessionState::Diffusion(net) => {
                // the group's served model is the consensus mean θ (equal
                // to every node's estimate once disagreement → 0)
                let theta = net.theta_mean();
                let mut out = [0.0];
                net.map().predict_batch_into(x, &theta, &mut out);
                out[0]
            }
            SessionState::PjrtKlms { map, theta, .. }
            | SessionState::PjrtKrls { map, theta, .. } => {
                // lane feature map + strictly sequential mixed dot: f32→f64
                // widening is exact, so this is bitwise identical to the
                // PredictState path (which widens θ once and runs the
                // sequential fused kernel)
                let z = map.apply(x);
                crate::linalg::simd::seq_dot_f64_f32(&z, theta)
            }
        }
    }

    /// Ingest one labelled sample. Native backends return the a-priori
    /// error immediately; the PJRT backend buffers and returns errors in
    /// batches of `chunk_n` (empty vec while the chunk fills).
    ///
    /// Stats: `samples_seen` moves only for rows whose update was applied
    /// — a failed chunk dispatch drops the chunk's rows and counts none
    /// of them (regression: it used to count them anyway, drifting from
    /// `running_mse`).
    pub fn train(&mut self, x: &[f64], y: f64) -> Result<Vec<f64>> {
        anyhow::ensure!(x.len() == self.config.dim, "sample dim mismatch");
        match &mut self.state {
            SessionState::NativeKlms(f) => {
                let e = f.step(x, y);
                self.samples_seen += 1;
                self.sum_sq_err += e * e;
                Ok(vec![e])
            }
            SessionState::NativeKrls(f) => {
                let e = f.step(x, y);
                self.samples_seen += 1;
                self.sum_sq_err += e * e;
                Ok(vec![e])
            }
            SessionState::NativeNlms(f) => {
                let e = f.step(x, y);
                self.samples_seen += 1;
                self.sum_sq_err += e * e;
                Ok(vec![e])
            }
            SessionState::Diffusion(_) => anyhow::bail!(
                "diffusion groups train on whole rounds (one row per node); \
                 use TrainDiffusion"
            ),
            SessionState::PjrtKlms { .. } | SessionState::PjrtKrls { .. } => {
                self.pjrt_push(x, y)
            }
        }
    }

    /// Ingest `n` labelled rows in one call: `xs` is row-major `[n, dim]`,
    /// `ys` the `n` targets; returns every a-priori error that became
    /// available, in row order. Native backends run the filters' blocked
    /// batch kernels — **bitwise identical** to `n` per-row [`Self::train`]
    /// calls, just faster. The PJRT backend buffers rows and dispatches as
    /// many whole chunks as the rows complete — one *request* can
    /// dispatch several chunks (each chunk is still its own executor
    /// round-trip; what the batch amortizes is queue/channel overhead) —
    /// leaving any remainder buffered for the next call/flush.
    ///
    /// On a chunk-dispatch error the failed chunk's rows are dropped and
    /// not counted; chunks already dispatched by the same call remain
    /// applied and counted.
    pub fn train_batch(&mut self, xs: &[f64], ys: &[f64]) -> Result<Vec<f64>> {
        let d = self.config.dim;
        anyhow::ensure!(
            xs.len() == ys.len() * d,
            "train_batch shape mismatch: xs must be [n, dim], ys length n"
        );
        match &mut self.state {
            SessionState::NativeKlms(f) => {
                let errs = f.train_batch(d, xs, ys);
                self.samples_seen += errs.len();
                self.sum_sq_err += errs.iter().map(|e| e * e).sum::<f64>();
                Ok(errs)
            }
            SessionState::NativeKrls(f) => {
                let errs = f.train_batch(d, xs, ys);
                self.samples_seen += errs.len();
                self.sum_sq_err += errs.iter().map(|e| e * e).sum::<f64>();
                Ok(errs)
            }
            SessionState::NativeNlms(f) => {
                let errs = f.train_batch(d, xs, ys);
                self.samples_seen += errs.len();
                self.sum_sq_err += errs.iter().map(|e| e * e).sum::<f64>();
                Ok(errs)
            }
            SessionState::Diffusion(_) => anyhow::bail!(
                "diffusion groups train on whole rounds (one row per node); \
                 use TrainDiffusion"
            ),
            SessionState::PjrtKlms { .. } | SessionState::PjrtKrls { .. } => {
                let mut out = Vec::new();
                for (row, &y) in xs.chunks_exact(d).zip(ys) {
                    out.extend(self.pjrt_push(row, y)?);
                }
                Ok(out)
            }
        }
    }

    /// Train a diffusion group on a window of whole rounds: `xs` is
    /// row-major `[rounds · nodes, dim]` in round-major order (round
    /// `r`'s node `k` is row `r·nodes + k`), `ys` the matching targets.
    /// Runs [`DiffusionNetwork::step_batch_into`] — the blocked batch
    /// kernels over the whole window, **bitwise identical** to stepping
    /// round by round — and returns every per-node a-priori error in row
    /// order. Errors on non-group sessions and on partial rounds.
    pub fn train_diffusion(&mut self, xs: &[f64], ys: &[f64]) -> Result<Vec<f64>> {
        let d = self.config.dim;
        anyhow::ensure!(
            xs.len() == ys.len() * d,
            "train_diffusion shape mismatch: xs must be [rows, dim], ys length rows"
        );
        let SessionState::Diffusion(net) = &mut self.state else {
            anyhow::bail!("session is not a diffusion group")
        };
        let n = net.nodes();
        anyhow::ensure!(
            !ys.is_empty() && ys.len() % n == 0,
            "diffusion window of {} rows is not whole rounds of {n} nodes",
            ys.len()
        );
        let mut errs = vec![0.0; ys.len()];
        net.step_batch_into(xs, ys, &mut errs);
        self.samples_seen += errs.len();
        self.sum_sq_err += errs.iter().map(|e| e * e).sum::<f64>();
        Ok(errs)
    }

    /// Buffer one row on a PJRT session, dispatching the chunk when full.
    fn pjrt_push(&mut self, x: &[f64], y: f64) -> Result<Vec<f64>> {
        match &mut self.state {
            SessionState::PjrtKlms { buf_x, buf_y, chunk_n, .. } => {
                buf_x.extend(x.iter().map(|&v| v as f32));
                buf_y.push(y as f32);
                if buf_y.len() < *chunk_n {
                    return Ok(Vec::new());
                }
                self.run_klms_chunk()
            }
            SessionState::PjrtKrls { buf_x, buf_y, chunk_n, .. } => {
                buf_x.extend(x.iter().map(|&v| v as f32));
                buf_y.push(y as f32);
                if buf_y.len() < *chunk_n {
                    return Ok(Vec::new());
                }
                self.run_krls_chunk()
            }
            _ => unreachable!("pjrt_push on a native session"),
        }
    }

    fn run_klms_chunk(&mut self) -> Result<Vec<f64>> {
        let handle = self.executor.as_ref().expect("pjrt session has executor").clone();
        let (d, features) = (self.config.dim, self.config.features);
        let SessionState::PjrtKlms { map, theta, mu, buf_x, buf_y, .. } = &mut self.state
        else {
            unreachable!()
        };
        // the f32 (Ω, b) staging tensors come from the map's shared
        // cached view — per-dispatch clones, no per-session copy
        let view = Arc::clone(map.f32_view());
        // θ is cloned (not taken) so a failed dispatch loses only the
        // chunk's rows, never the learned state
        let (theta_new, errs) = handle.klms_chunk(
            d,
            features,
            theta.clone(),
            std::mem::take(buf_x),
            std::mem::take(buf_y),
            view.omega.clone(),
            view.phases.clone(),
            *mu,
        )?;
        *theta = theta_new;
        let errs: Vec<f64> = errs.into_iter().map(|e| e as f64).collect();
        self.samples_seen += errs.len();
        self.sum_sq_err += errs.iter().map(|e| e * e).sum::<f64>();
        Ok(errs)
    }

    fn run_krls_chunk(&mut self) -> Result<Vec<f64>> {
        let handle = self.executor.as_ref().expect("pjrt session has executor").clone();
        let (d, features) = (self.config.dim, self.config.features);
        let SessionState::PjrtKrls { map, theta, p, beta, buf_x, buf_y, .. } = &mut self.state
        else {
            unreachable!()
        };
        // shared cached f32 staging view, as in `run_klms_chunk`
        let view = Arc::clone(map.f32_view());
        // θ/P are cloned (not taken) so a failed dispatch loses only the
        // chunk's rows, never the learned state
        let (theta_new, p_new, errs) = handle.krls_chunk(
            d,
            features,
            theta.clone(),
            p.clone(),
            std::mem::take(buf_x),
            std::mem::take(buf_y),
            view.omega.clone(),
            view.phases.clone(),
            *beta,
        )?;
        *theta = theta_new;
        *p = p_new;
        let errs: Vec<f64> = errs.into_iter().map(|e| e as f64).collect();
        self.samples_seen += errs.len();
        self.sum_sq_err += errs.iter().map(|e| e * e).sum::<f64>();
        Ok(errs)
    }

    /// Flush a partially filled PJRT chunk by finishing the remainder
    /// through the shared [`native_step`] kernels (the same
    /// mathematically-matching f32 recipe the integration tests bound
    /// against the artifact). Returns the remainder's errors, which are
    /// counted into `samples_seen` here (buffered rows are not counted at
    /// buffer time). No-op for native sessions.
    pub fn flush(&mut self) -> Result<Vec<f64>> {
        let errs = match &mut self.state {
            SessionState::NativeKlms(_)
            | SessionState::NativeKrls(_)
            | SessionState::NativeNlms(_)
            | SessionState::Diffusion(_) => Vec::new(),
            SessionState::PjrtKlms { map, theta, mu, buf_x, buf_y, .. } => {
                let d = map.dim();
                let mut errs = Vec::with_capacity(buf_y.len());
                let mut z = vec![0.0f64; theta.len()];
                let mut x = vec![0.0f64; d];
                for (row, &y) in buf_x.chunks(d).zip(buf_y.iter()) {
                    for (xo, &xi) in x.iter_mut().zip(row) {
                        *xo = xi as f64;
                    }
                    errs.push(native_step::klms_step(map, theta, *mu, &x, y, &mut z));
                }
                buf_x.clear();
                buf_y.clear();
                errs
            }
            SessionState::PjrtKrls { map, theta, p, beta, buf_x, buf_y, .. } => {
                let d = map.dim();
                let features = theta.len();
                let mut errs = Vec::with_capacity(buf_y.len());
                let mut z = vec![0.0f64; features];
                let mut pi = vec![0.0f64; features];
                let mut x = vec![0.0f64; d];
                for (row, &y) in buf_x.chunks(d).zip(buf_y.iter()) {
                    for (xo, &xi) in x.iter_mut().zip(row) {
                        *xo = xi as f64;
                    }
                    errs.push(native_step::krls_step(
                        map, theta, p, *beta, &x, y, &mut z, &mut pi,
                    ));
                }
                buf_x.clear();
                buf_y.clear();
                errs
            }
        };
        self.samples_seen += errs.len();
        self.sum_sq_err += errs.iter().map(|e| e * e).sum::<f64>();
        Ok(errs)
    }

    /// Capture a [`SessionSnapshot`] of this session's complete state:
    /// config, map (by reference when the session has a [`MapSpec`],
    /// inline otherwise), learned θ/P, any buffered partial PJRT chunk
    /// rows, and the running stats. Pure read — no flush, no dispatch;
    /// buffered rows are carried in the snapshot, not dropped.
    pub fn snapshot(&self) -> SessionSnapshot {
        let map = match self.map_spec {
            // an adaptive session's Ω (may have) diverged from its spec's
            // initial draw — a reference would silently restore the draw,
            // so adaptive maps always serialize their private Ω inline
            Some(spec) if !self.map_arc().kind().is_adaptive() => {
                MapPayload::Reference(spec)
            }
            _ => MapPayload::Inline(Arc::clone(self.map_arc())),
        };
        let state = match &self.state {
            SessionState::NativeKlms(f) => {
                SnapshotState::NativeKlms { theta: f.theta().to_vec() }
            }
            SessionState::NativeKrls(f) => SnapshotState::NativeKrls {
                theta: f.theta().to_vec(),
                // the filter's live packed upper triangle — no dense
                // reconstruction on the snapshot path
                p_packed: f.p_packed().to_vec(),
            },
            SessionState::NativeNlms(f) => {
                SnapshotState::NativeNlms { theta: f.theta().to_vec() }
            }
            SessionState::Diffusion(net) => SnapshotState::Diffusion {
                state: crate::distributed::DiffusionState::of(net),
            },
            SessionState::PjrtKlms { theta, buf_x, buf_y, .. } => SnapshotState::PjrtKlms {
                theta: theta.clone(),
                buf_x: buf_x.clone(),
                buf_y: buf_y.clone(),
            },
            SessionState::PjrtKrls { theta, p, buf_x, buf_y, .. } => SnapshotState::PjrtKrls {
                theta: theta.clone(),
                p: p.clone(),
                buf_x: buf_x.clone(),
                buf_y: buf_y.clone(),
            },
        };
        SessionSnapshot {
            config: self.config.clone(),
            map,
            state,
            samples_seen: self.samples_seen,
            sum_sq_err: self.sum_sq_err,
        }
    }

    /// Rebuild a session from a snapshot. Reference-mode maps resolve
    /// through `registry` (sharing the fleet's interned copy; a missing
    /// registry re-draws the identical map standalone); `executor` is
    /// required for PJRT-backend snapshots, exactly as at construction.
    ///
    /// Exactness: restoring a native session and continuing to train
    /// produces errors/θ/P **bitwise identical** to the uninterrupted
    /// run; f32 PJRT state also round-trips bitwise, with buffered
    /// partial chunk rows re-buffered, so the next chunk dispatch sees
    /// exactly what it would have.
    pub fn restore(
        snap: SessionSnapshot,
        registry: Option<&MapRegistry>,
        executor: Option<ExecutorHandle>,
    ) -> Result<Self> {
        let spec = snap.map.spec();
        let map = snap.map.resolve(registry);
        if let SnapshotState::Diffusion { state } = snap.state {
            // diffusion groups rebuild through their own constructor: the
            // topology round-trips via its canonical edge list, so the
            // combine order — and with it the trajectory — is bitwise
            // preserved
            let topology = state.build_topology(map.features())?;
            let config = DiffusionGroupConfig {
                session: snap.config,
                ordering: state.ordering,
                topology,
            };
            let mut s = Self::build_diffusion(config, map, spec)?;
            let SessionState::Diffusion(net) = &mut s.state else { unreachable!() };
            net.restore_thetas(state.thetas);
            s.samples_seen = snap.samples_seen;
            s.sum_sq_err = snap.sum_sq_err;
            return Ok(s);
        }
        let mut s = Self::build(snap.config, map, spec, executor)?;
        let feats = s.config.features;
        match (&mut s.state, snap.state) {
            (SessionState::NativeKlms(f), SnapshotState::NativeKlms { theta }) => {
                anyhow::ensure!(theta.len() == feats, "theta length mismatch");
                f.set_theta(theta);
            }
            (SessionState::NativeNlms(f), SnapshotState::NativeNlms { theta }) => {
                anyhow::ensure!(theta.len() == feats, "theta length mismatch");
                f.set_theta(theta);
            }
            (SessionState::NativeKrls(f), SnapshotState::NativeKrls { theta, p_packed }) => {
                anyhow::ensure!(
                    theta.len() == feats
                        && p_packed.len() == crate::linalg::simd::packed_len(feats),
                    "state shape mismatch"
                );
                f.restore_state_packed(theta, p_packed);
            }
            (
                SessionState::PjrtKlms { theta, buf_x, buf_y, chunk_n, .. },
                SnapshotState::PjrtKlms { theta: t, buf_x: bx, buf_y: by },
            ) => {
                anyhow::ensure!(t.len() == feats, "theta length mismatch");
                anyhow::ensure!(bx.len() == by.len() * s.config.dim, "buffer shape mismatch");
                anyhow::ensure!(
                    by.len() < *chunk_n,
                    "snapshot buffers {} rows but the current artifact chunk is {} — \
                     restore against the artifact set the snapshot was taken with",
                    by.len(),
                    *chunk_n
                );
                *theta = t;
                *buf_x = bx;
                *buf_y = by;
            }
            (
                SessionState::PjrtKrls { theta, p, buf_x, buf_y, chunk_n, .. },
                SnapshotState::PjrtKrls { theta: t, p: pp, buf_x: bx, buf_y: by },
            ) => {
                anyhow::ensure!(
                    t.len() == feats && pp.len() == feats * feats,
                    "state shape mismatch"
                );
                anyhow::ensure!(bx.len() == by.len() * s.config.dim, "buffer shape mismatch");
                anyhow::ensure!(
                    by.len() < *chunk_n,
                    "snapshot buffers {} rows but the current artifact chunk is {} — \
                     restore against the artifact set the snapshot was taken with",
                    by.len(),
                    *chunk_n
                );
                *theta = t;
                *p = pp;
                *buf_x = bx;
                *buf_y = by;
            }
            _ => anyhow::bail!("snapshot state does not match its config's backend/algo"),
        }
        s.samples_seen = snap.samples_seen;
        s.sum_sq_err = snap.sum_sq_err;
        Ok(s)
    }

    /// Approximate heap bytes of this session's **own** state — θ, P,
    /// scratch and chunk buffers — excluding the shared map (count that
    /// once per fleet via [`RffMap::heap_bytes`](crate::kaf::FeatureMap::heap_bytes)). The per-session
    /// marginal cost the §Memory protocol records. Native variants
    /// delegate to the filters' own accounting, so the KRLS number
    /// reflects the packed `D(D+1)/2` P (about half the dense layout at
    /// large D); the PJRT KRLS `P` stays dense f32 — the device
    /// artifact's layout.
    pub fn state_bytes(&self) -> usize {
        match &self.state {
            SessionState::NativeKlms(f) => f.heap_bytes(),
            SessionState::NativeKrls(f) => f.heap_bytes(),
            SessionState::NativeNlms(f) => f.heap_bytes(),
            SessionState::Diffusion(net) => net.heap_bytes(),
            SessionState::PjrtKlms { theta, buf_x, buf_y, .. } => {
                (theta.len() + buf_x.capacity() + buf_y.capacity()) * 4
            }
            SessionState::PjrtKrls { theta, p, buf_x, buf_y, .. } => {
                (theta.len() + p.len() + buf_x.capacity() + buf_y.capacity()) * 4
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::run_rng;
    use crate::signal::{NonlinearWiener, SignalSource};

    #[test]
    fn native_session_learns() {
        let mut rng = run_rng(1, 0);
        let mut s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let mut src = NonlinearWiener::new(run_rng(1, 1), 0.05);
        let mut first = 0.0;
        let mut last = 0.0;
        for (i, smp) in src.take_samples(4000).iter().enumerate() {
            let e = s.train(&smp.x, smp.y).unwrap()[0];
            if i < 200 {
                first += e * e;
            }
            if i >= 3800 {
                last += e * e;
            }
        }
        assert!(last < first * 0.25, "first={first} last={last}");
        assert_eq!(s.samples_seen(), 4000);
        assert!(s.running_mse() > 0.0);
    }

    #[test]
    fn krls_native_session_works() {
        let cfg = SessionConfig {
            algo: Algo::RffKrls { beta: 0.9995, lambda: 1e-4 },
            features: 100,
            ..SessionConfig::paper_default()
        };
        let mut rng = run_rng(2, 0);
        let mut s = FilterSession::new(cfg, &mut rng, None).unwrap();
        let mut src = NonlinearWiener::new(run_rng(2, 1), 0.05);
        for smp in src.take_samples(500) {
            s.train(&smp.x, smp.y).unwrap();
        }
        let mut src2 = NonlinearWiener::new(run_rng(2, 1), 0.05);
        let test = src2.take_samples(600);
        let tail = &test[500..];
        let mse: f64 =
            tail.iter().map(|t| (s.predict(&t.x) - t.clean).powi(2)).sum::<f64>() / 100.0;
        assert!(mse < 0.5, "predict mse {mse}");
    }

    #[test]
    fn pjrt_backend_requires_executor() {
        let cfg = SessionConfig { backend: Backend::Pjrt, ..SessionConfig::paper_default() };
        let mut rng = run_rng(3, 0);
        assert!(FilterSession::new(cfg, &mut rng, None).is_err());
    }

    #[test]
    fn flush_noop_on_native() {
        let mut rng = run_rng(4, 0);
        let mut s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        assert!(s.flush().unwrap().is_empty());
    }

    #[test]
    fn predict_state_matches_live_session() {
        let mut rng = run_rng(6, 0);
        let mut s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let mut src = NonlinearWiener::new(run_rng(6, 1), 0.05);
        for smp in src.take_samples(500) {
            s.train(&smp.x, smp.y).unwrap();
        }
        let snap = s.predict_state();
        assert_eq!(snap.dim(), 5);
        assert_eq!(snap.features(), 300);
        assert_eq!(snap.theta_f32().len(), 300);
        for smp in src.take_samples(20) {
            assert_eq!(snap.predict(&smp.x), s.predict(&smp.x));
        }
        // the snapshot is detached: further training must not change it
        let frozen = snap.theta().to_vec();
        let probe = src.take_samples(50);
        for smp in &probe {
            s.train(&smp.x, smp.y).unwrap();
        }
        assert_eq!(snap.theta(), &frozen[..]);
        assert_ne!(s.theta(), frozen);
    }

    #[test]
    fn failed_chunk_dispatch_counts_no_samples() {
        // regression: samples_seen used to be incremented at buffer time,
        // so a failed chunk dispatch left it disagreeing with the errors
        // folded into running_mse
        let handle = ExecutorHandle::failing_stub(4);
        let cfg = SessionConfig { backend: Backend::Pjrt, ..SessionConfig::paper_default() };
        let mut rng = run_rng(7, 0);
        let mut s = FilterSession::new(cfg, &mut rng, Some(handle)).unwrap();
        let x = [0.1, 0.2, -0.3, 0.4, 0.0];
        // buffered rows are pending, not yet "seen"
        for _ in 0..3 {
            assert!(s.train(&x, 0.5).unwrap().is_empty());
        }
        assert_eq!(s.samples_seen(), 0);
        // the 4th row completes the chunk; the injected dispatch failure
        // must drop the chunk without counting any of its rows
        assert!(s.train(&x, 0.5).is_err());
        assert_eq!(s.samples_seen(), 0);
        assert_eq!(s.running_mse(), 0.0);
        // the buffer was consumed by the failed dispatch: nothing to flush
        assert!(s.flush().unwrap().is_empty());
        assert_eq!(s.samples_seen(), 0);
        // learned state survives the failure (θ is cloned, not taken, for
        // the dispatch) and the session stays usable
        assert_eq!(s.theta().len(), 300);
        assert!(s.train(&x, 0.5).unwrap().is_empty()); // buffers again
        let errs = s.flush().unwrap();
        assert_eq!(errs.len(), 1);
        assert_eq!(s.samples_seen(), 1);
    }

    #[test]
    fn flush_counts_remainder_rows() {
        // remainder rows become "seen" when flush() applies them natively
        let handle = ExecutorHandle::failing_stub(64);
        let cfg = SessionConfig { backend: Backend::Pjrt, ..SessionConfig::paper_default() };
        let mut rng = run_rng(8, 0);
        let mut s = FilterSession::new(cfg, &mut rng, Some(handle)).unwrap();
        let mut src = NonlinearWiener::new(run_rng(8, 1), 0.05);
        for smp in src.take_samples(5) {
            assert!(s.train(&smp.x, smp.y).unwrap().is_empty());
        }
        assert_eq!(s.samples_seen(), 0); // buffered, not yet applied
        let errs = s.flush().unwrap();
        assert_eq!(errs.len(), 5);
        assert_eq!(s.samples_seen(), 5);
        assert!(s.running_mse() > 0.0);
    }

    #[test]
    fn train_batch_native_matches_per_row_session() {
        let mut rng = run_rng(9, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 300);
        let cfg = SessionConfig::paper_default();
        let mut per_row = FilterSession::with_map(cfg.clone(), map.clone(), None).unwrap();
        let mut batched = FilterSession::with_map(cfg, map, None).unwrap();
        let mut src = NonlinearWiener::new(run_rng(9, 1), 0.05);
        let samples = src.take_samples(130);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut want = Vec::new();
        for smp in &samples {
            want.extend(per_row.train(&smp.x, smp.y).unwrap());
            xs.extend_from_slice(&smp.x);
            ys.push(smp.y);
        }
        let got = batched.train_batch(&xs, &ys).unwrap();
        assert_eq!(got, want, "batched errors must equal per-row errors bitwise");
        assert_eq!(batched.samples_seen(), per_row.samples_seen());
        assert_eq!(batched.theta(), per_row.theta());
        // batched predictions off the snapshot equal per-row predicts
        let snap = batched.predict_state();
        let mut out = vec![0.0; ys.len()];
        snap.predict_batch(&xs, &mut out);
        for (r, &v) in out.iter().enumerate() {
            assert_eq!(v, per_row.predict(&xs[r * 5..(r + 1) * 5]));
        }
        // shape mismatch rejected before any row is applied
        assert!(batched.train_batch(&xs[..7], &ys[..2]).is_err());
        assert_eq!(batched.samples_seen(), per_row.samples_seen());
    }

    #[test]
    fn dim_mismatch_rejected() {
        let mut rng = run_rng(5, 0);
        let mut s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        assert!(s.train(&[1.0, 2.0], 0.5).is_err());
    }

    #[test]
    fn map_config_mismatch_rejected() {
        let mut rng = run_rng(10, 0);
        let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 100);
        // config says D=300, map has D=100
        assert!(FilterSession::with_map(SessionConfig::paper_default(), map, None).is_err());
    }

    #[test]
    fn fleet_of_spec_sessions_shares_one_map() {
        // acceptance gate: N same-config sessions hold exactly ONE
        // resident (Ω, b) — the registry's copy
        let registry = MapRegistry::new();
        let cfg = SessionConfig { features: 32, ..SessionConfig::paper_default() };
        let sessions: Vec<FilterSession> = (0..10)
            .map(|_| FilterSession::from_spec(cfg.clone(), 42, &registry, None).unwrap())
            .collect();
        let spec = MapSpec::new(cfg.kernel, cfg.dim, cfg.features, 42);
        let map = registry.get_or_draw(&spec);
        // registry + 10 sessions + our probe handle
        assert_eq!(Arc::strong_count(&map), 12);
        assert_eq!(registry.len(), 1);
        assert_eq!(registry.misses(), 1);
        for s in &sessions {
            assert!(Arc::ptr_eq(s.map_arc(), &map));
            assert_eq!(s.map_spec(), Some(spec));
        }
        // KRLS sessions share the same interned map too
        let krls_cfg = SessionConfig {
            algo: Algo::RffKrls { beta: 0.9995, lambda: 1e-4 },
            ..cfg
        };
        let k = FilterSession::from_spec(krls_cfg, 42, &registry, None).unwrap();
        assert!(Arc::ptr_eq(k.map_arc(), &map));
        assert_eq!(registry.len(), 1);
    }

    #[test]
    fn adaptive_fleet_copy_on_adapt_semantics() {
        // acceptance gate: an adaptive fleet shares ONE resident initial
        // draw until sessions adapt — then exactly one clone per adapted
        // session, never before the first Ω update
        let registry = MapRegistry::new();
        let cfg = SessionConfig { features: 32, ..SessionConfig::paper_default() };
        let spec = MapSpec::adaptive(cfg.kernel, cfg.dim, cfg.features, 42, 0.01);
        let mut sessions: Vec<FilterSession> = (0..4)
            .map(|_| FilterSession::from_map_spec(cfg.clone(), spec, &registry, None).unwrap())
            .collect();
        let map = registry.get_or_draw(&spec);
        // registry + 4 sessions + probe: no clones before any update
        assert_eq!(Arc::strong_count(&map), 6);
        // train two of the four: each detaches exactly one private copy
        let mut src = NonlinearWiener::new(run_rng(41, 0), 0.05);
        for smp in src.take_samples(10) {
            sessions[0].train(&smp.x, smp.y).unwrap();
            sessions[1].train(&smp.x, smp.y).unwrap();
        }
        assert_eq!(Arc::strong_count(&map), 4, "two sessions cloned, two still share");
        assert!(!Arc::ptr_eq(sessions[0].map_arc(), &map));
        assert!(!Arc::ptr_eq(sessions[0].map_arc(), sessions[1].map_arc()));
        assert!(Arc::ptr_eq(sessions[2].map_arc(), &map));
        // identical trajectories → identical (private) adapted maps
        assert_eq!(sessions[0].map().omega(0), sessions[1].map().omega(0));
        assert_ne!(sessions[0].map().omega(0), map.omega(0));
    }

    #[test]
    fn adaptive_session_snapshot_is_inline_and_restores_bitwise() {
        let registry = MapRegistry::new();
        let cfg = SessionConfig { features: 24, ..SessionConfig::paper_default() };
        let spec = MapSpec::adaptive(cfg.kernel, cfg.dim, cfg.features, 7, 0.02);
        let mut s = FilterSession::from_map_spec(cfg, spec, &registry, None).unwrap();
        let mut src = NonlinearWiener::new(run_rng(42, 0), 0.05);
        for smp in src.take_samples(50) {
            s.train(&smp.x, smp.y).unwrap();
        }
        // spec session, but adaptive ⇒ the snapshot must carry Ω inline
        let snap = s.snapshot();
        assert!(snap.map_spec().is_none(), "adaptive snapshot must not be a reference");
        let text = snap.to_json();
        assert!(text.contains("\"kind\":\"adaptive_rff\""));
        let mut restored = FilterSession::restore(
            SessionSnapshot::from_json(&text).unwrap(),
            Some(&registry),
            None,
        )
        .unwrap();
        assert_eq!(restored.theta(), s.theta());
        assert_eq!(restored.map().omega(5), s.map().omega(5));
        for smp in src.take_samples(30) {
            assert_eq!(
                s.train(&smp.x, smp.y).unwrap(),
                restored.train(&smp.x, smp.y).unwrap(),
                "continuation diverged (Ω and θ must co-evolve identically)"
            );
        }
    }

    #[test]
    fn quadrature_session_round_trips_by_reference() {
        let registry = MapRegistry::new();
        let kernel = Kernel::Gaussian { sigma: 1.0 };
        let spec = MapSpec::quadrature(kernel, 2, 4).unwrap();
        let cfg = SessionConfig {
            dim: 2,
            features: spec.features,
            kernel,
            algo: Algo::RffKlms { mu: 0.5 },
            backend: Backend::Native,
        };
        let mut s = FilterSession::from_map_spec(cfg, spec, &registry, None).unwrap();
        for i in 0..60 {
            let t = i as f64 * 0.23;
            s.train(&[t.sin(), t.cos()], (t * 0.7).sin()).unwrap();
        }
        let text = s.snapshot().to_json();
        assert!(text.contains("\"mode\":\"reference\""));
        assert!(text.contains("\"kind\":\"quadrature\""));
        let restored = FilterSession::restore(
            SessionSnapshot::from_json(&text).unwrap(),
            Some(&registry),
            None,
        )
        .unwrap();
        // the restored session SHARES the interned deterministic grid
        assert!(Arc::ptr_eq(restored.map_arc(), s.map_arc()));
        assert_eq!(restored.theta(), s.theta());
    }

    #[test]
    fn map_kind_gates_reject_unsupported_combinations() {
        let registry = MapRegistry::new();
        let cfg = SessionConfig { features: 16, ..SessionConfig::paper_default() };
        let aspec = MapSpec::adaptive(cfg.kernel, cfg.dim, cfg.features, 1, 0.01);
        // adaptive + KRLS: rejected (only RFF-KLMS runs the Ω gradient)
        let krls = SessionConfig {
            algo: Algo::RffKrls { beta: 0.999, lambda: 1e-3 },
            ..cfg.clone()
        };
        let err = FilterSession::from_map_spec(krls, aspec, &registry, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("RFF-KLMS"), "unhelpful: {err}");
        // adaptive + PJRT: rejected before the executor is even consulted
        let pjrt = SessionConfig { backend: Backend::Pjrt, ..cfg.clone() };
        let err = FilterSession::from_map_spec(pjrt, aspec, &registry, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("static RFF"), "unhelpful: {err}");
        // adaptive diffusion group: rejected (nodes exchange θ only)
        let amap = registry.get_or_draw(&aspec);
        let group = DiffusionGroupConfig {
            session: cfg,
            ordering: DiffusionOrdering::CombineThenAdapt,
            topology: NetworkTopology::ring(3),
        };
        let err = FilterSession::diffusion_with_map(group, amap)
            .unwrap_err()
            .to_string();
        assert!(err.contains("frozen map kind"), "unhelpful: {err}");
    }

    #[test]
    fn quadrature_diffusion_group_trains() {
        // any *static* kind is diffusion-eligible — quadrature included
        let kernel = Kernel::Gaussian { sigma: 1.0 };
        let map = RffMap::quadrature(kernel, 2, 3).unwrap();
        let group = DiffusionGroupConfig {
            session: SessionConfig {
                dim: 2,
                features: map.features(),
                kernel,
                algo: Algo::RffKlms { mu: 0.2 },
                backend: Backend::Native,
            },
            ordering: DiffusionOrdering::CombineThenAdapt,
            topology: NetworkTopology::ring(3),
        };
        let mut s = FilterSession::diffusion_with_map(group, map).unwrap();
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..30 {
            let t = i as f64 * 0.31;
            xs.extend_from_slice(&[t.sin(), t.cos()]);
            ys.push((t * 0.9).sin());
        }
        let errs = s.train_diffusion(&xs, &ys).unwrap();
        assert_eq!(errs.len(), 30);
        assert!(errs.iter().all(|e| e.is_finite()));
    }

    #[test]
    fn snapshot_restore_native_is_bitwise() {
        for algo in [
            Algo::RffKlms { mu: 1.0 },
            Algo::RffKrls { beta: 0.9995, lambda: 1e-4 },
        ] {
            let cfg = SessionConfig { algo, features: 24, ..SessionConfig::paper_default() };
            let mut rng = run_rng(11, 0);
            let mut live = FilterSession::new(cfg, &mut rng, None).unwrap();
            let mut src = NonlinearWiener::new(run_rng(11, 1), 0.05);
            for smp in src.take_samples(80) {
                live.train(&smp.x, smp.y).unwrap();
            }
            let text = live.snapshot().to_json();
            let snap = SessionSnapshot::from_json(&text).unwrap();
            let mut restored = FilterSession::restore(snap, None, None).unwrap();
            assert_eq!(restored.samples_seen(), live.samples_seen());
            assert_eq!(restored.running_mse(), live.running_mse());
            assert_eq!(restored.theta(), live.theta());
            // bitwise-identical continuation
            for smp in src.take_samples(60) {
                let a = live.train(&smp.x, smp.y).unwrap();
                let b = restored.train(&smp.x, smp.y).unwrap();
                assert_eq!(a, b, "continuation diverged");
            }
            assert_eq!(restored.theta(), live.theta());
        }
    }

    #[test]
    fn snapshot_restore_carries_buffered_pjrt_rows() {
        // buffered partial-chunk rows survive snapshot → restore: flushing
        // the restored session applies them (nothing silently dropped)
        let handle = ExecutorHandle::failing_stub(8);
        let cfg = SessionConfig { backend: Backend::Pjrt, ..SessionConfig::paper_default() };
        let mut rng = run_rng(12, 0);
        let mut s = FilterSession::new(cfg, &mut rng, Some(handle.clone())).unwrap();
        let mut src = NonlinearWiener::new(run_rng(12, 1), 0.05);
        for smp in src.take_samples(3) {
            assert!(s.train(&smp.x, smp.y).unwrap().is_empty()); // buffering
        }
        assert_eq!(s.samples_seen(), 0);
        let text = s.snapshot().to_json();
        let snap = SessionSnapshot::from_json(&text).unwrap();
        let mut restored = FilterSession::restore(snap, None, Some(handle)).unwrap();
        // the original's flush and the restored one's flush agree exactly
        let want = s.flush().unwrap();
        let got = restored.flush().unwrap();
        assert_eq!(want.len(), 3);
        assert_eq!(got, want, "restored buffered rows diverged");
        assert_eq!(restored.samples_seen(), 3);
        assert_eq!(restored.theta(), s.theta());
    }

    #[test]
    fn spec_session_snapshot_is_a_reference() {
        // interned sessions snapshot the map by spec: tiny document, and
        // restore shares the registry's copy instead of allocating one
        let registry = MapRegistry::new();
        let cfg = SessionConfig { features: 64, ..SessionConfig::paper_default() };
        let mut s = FilterSession::from_spec(cfg.clone(), 5, &registry, None).unwrap();
        let mut src = NonlinearWiener::new(run_rng(13, 1), 0.05);
        for smp in src.take_samples(50) {
            s.train(&smp.x, smp.y).unwrap();
        }
        let by_ref = s.snapshot().to_json();
        let inline = {
            // same state, inline map for comparison
            let mut t = FilterSession::with_map(cfg, Arc::clone(s.map_arc()), None).unwrap();
            for smp in NonlinearWiener::new(run_rng(13, 1), 0.05).take_samples(50) {
                t.train(&smp.x, smp.y).unwrap();
            }
            t.snapshot().to_json()
        };
        assert!(
            by_ref.len() * 2 < inline.len(),
            "reference snapshot ({}) should be far smaller than inline ({})",
            by_ref.len(),
            inline.len()
        );
        let snap = SessionSnapshot::from_json(&by_ref).unwrap();
        assert!(snap.map_spec().is_some());
        let restored = FilterSession::restore(snap, Some(&registry), None).unwrap();
        assert!(Arc::ptr_eq(restored.map_arc(), s.map_arc()));
        assert_eq!(restored.theta(), s.theta());
    }

    #[test]
    fn nlms_native_session_learns_and_snapshots_bitwise() {
        let cfg = SessionConfig {
            algo: Algo::RffNlms { mu: 0.5, eps: 1e-6 },
            features: 64,
            ..SessionConfig::paper_default()
        };
        let mut rng = run_rng(30, 0);
        let mut live = FilterSession::new(cfg.clone(), &mut rng, None).unwrap();
        let mut src = NonlinearWiener::new(run_rng(30, 1), 0.05);
        for smp in src.take_samples(400) {
            live.train(&smp.x, smp.y).unwrap();
        }
        assert_eq!(live.samples_seen(), 400);
        // snapshot → restore → continue, bitwise
        let text = live.snapshot().to_json();
        assert!(text.contains("native_nlms"));
        let mut restored =
            FilterSession::restore(SessionSnapshot::from_json(&text).unwrap(), None, None)
                .unwrap();
        assert_eq!(restored.theta(), live.theta());
        for smp in src.take_samples(50) {
            let a = live.train(&smp.x, smp.y).unwrap();
            let b = restored.train(&smp.x, smp.y).unwrap();
            assert_eq!(a, b, "NLMS continuation diverged");
        }
        // and the PJRT backend correctly refuses NLMS
        let pjrt_cfg = SessionConfig { backend: Backend::Pjrt, ..cfg };
        let handle = ExecutorHandle::failing_stub(4);
        let mut rng2 = run_rng(30, 2);
        assert!(FilterSession::new(pjrt_cfg, &mut rng2, Some(handle)).is_err());
    }

    fn group_config(nodes: usize) -> DiffusionGroupConfig {
        DiffusionGroupConfig {
            session: SessionConfig { features: 32, ..SessionConfig::paper_default() },
            ordering: DiffusionOrdering::AdaptThenCombine,
            topology: NetworkTopology::ring(nodes),
        }
    }

    #[test]
    fn diffusion_group_session_trains_and_snapshots_bitwise() {
        let registry = MapRegistry::new();
        let mut live =
            FilterSession::diffusion_from_spec(group_config(3), 9, &registry).unwrap();
        let mut src = NonlinearWiener::new(run_rng(31, 1), 0.05);
        let round = |s: &crate::signal::Sample, sess: &mut FilterSession| {
            let mut xs = Vec::new();
            for _ in 0..3 {
                xs.extend_from_slice(&s.x);
            }
            sess.train_diffusion(&xs, &vec![s.y; 3]).unwrap()
        };
        for s in src.take_samples(60) {
            round(&s, &mut live);
        }
        assert_eq!(live.samples_seen(), 180); // rows = rounds × nodes
        assert!(live.running_mse() > 0.0);

        // interned group snapshots by reference and restores sharing the
        // registry's map
        let text = live.snapshot().to_json();
        assert!(text.contains("\"diffusion\"") && text.contains("\"reference\""));
        let mut restored = FilterSession::restore(
            SessionSnapshot::from_json(&text).unwrap(),
            Some(&registry),
            None,
        )
        .unwrap();
        assert!(Arc::ptr_eq(restored.map_arc(), live.map_arc()));
        assert_eq!(restored.samples_seen(), live.samples_seen());
        for s in src.take_samples(40) {
            let a = round(&s, &mut live);
            let b = round(&s, &mut restored);
            assert_eq!(a, b, "group continuation diverged after restore");
        }
        assert_eq!(
            restored.diffusion().unwrap().thetas(),
            live.diffusion().unwrap().thetas()
        );
        // the group's served prediction is the consensus mean
        let probe = [0.1, -0.2, 0.3, 0.0, 0.5];
        assert_eq!(restored.predict(&probe), live.predict(&probe));
    }

    #[test]
    fn diffusion_group_rejects_bad_configs_and_shapes() {
        let registry = MapRegistry::new();
        // KRLS adapt rule is not a diffusion workload
        let mut bad = group_config(3);
        bad.session.algo = Algo::RffKrls { beta: 0.999, lambda: 1e-3 };
        assert!(FilterSession::diffusion_from_spec(bad, 1, &registry).is_err());
        // PJRT backend is not either
        let mut bad = group_config(3);
        bad.session.backend = Backend::Pjrt;
        assert!(FilterSession::diffusion_from_spec(bad, 1, &registry).is_err());

        let mut group =
            FilterSession::diffusion_from_spec(group_config(3), 1, &registry).unwrap();
        // partial rounds are rejected before any row is applied
        assert!(group.train_diffusion(&[0.0; 10], &[0.0; 2]).is_err());
        assert_eq!(group.samples_seen(), 0);
        // per-sample and plain-batch training point at TrainDiffusion
        assert!(group.train(&[0.0; 5], 1.0).is_err());
        assert!(group.train_batch(&[0.0; 15], &[0.0; 3]).is_err());
        // and a non-group session rejects train_diffusion
        let mut rng = run_rng(32, 0);
        let mut plain =
            FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        assert!(plain.train_diffusion(&[0.0; 15], &[0.0; 3]).is_err());
    }

    #[test]
    fn corrupt_snapshot_rejected() {
        assert!(SessionSnapshot::from_json("{").is_err());
        assert!(SessionSnapshot::from_json("{\"format\":1}").is_err());
        assert!(SessionSnapshot::from_json("{\"format\":999}").is_err());
        // state/config mismatch is an error, not a panic
        let mut rng = run_rng(14, 0);
        let s =
            FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let text = s.snapshot().to_json().replace("native_klms", "native_krls");
        // shape check catches it at parse (θ is not D² long for P)
        assert!(SessionSnapshot::from_json(&text).is_err());
        // out-of-range hyperparameters are parse errors, not panics
        // inside a filter constructor during restore
        let bad_mu = s.snapshot().to_json().replace("\"mu\":1", "\"mu\":-1");
        assert!(SessionSnapshot::from_json(&bad_mu).is_err());
    }
}
