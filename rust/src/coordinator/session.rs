//! Filter sessions: one online-learning state machine per stream.
//!
//! A session is configured with an algorithm + kernel + feature map and a
//! backend:
//! * [`Backend::Native`] — pure-Rust per-sample updates (lowest latency).
//! * [`Backend::Pjrt`] — samples buffered into N-sample chunks executed
//!   by the AOT artifact via the [`ExecutorHandle`]; remainders at
//!   `flush()` run natively with matching math (f32 state, f64 features;
//!   the integration tests bound the difference against the artifact).

use std::sync::Arc;

use anyhow::Result;

use crate::kaf::kernels::Kernel;
use crate::kaf::{OnlineRegressor, RffKlms, RffKrls, RffMap};
use crate::rng::Rng;
use crate::runtime::ExecutorHandle;

/// Which algorithm a session runs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Algo {
    /// RFF-KLMS with step size μ.
    RffKlms {
        /// LMS step size.
        mu: f64,
    },
    /// RFF-KRLS with forgetting β and regularization λ.
    RffKrls {
        /// Forgetting factor.
        beta: f64,
        /// Regularization (P₀ = I/λ).
        lambda: f64,
    },
}

/// Execution backend for a session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust per-sample hot path.
    Native,
    /// Chunked AOT execution through PJRT.
    Pjrt,
}

/// Session configuration.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Input dimension d.
    pub dim: usize,
    /// Feature count D.
    pub features: usize,
    /// Kernel (bandwidth matters: frequencies are drawn from its
    /// spectral density).
    pub kernel: Kernel,
    /// Algorithm + hyperparameters.
    pub algo: Algo,
    /// Backend selection.
    pub backend: Backend,
}

impl SessionConfig {
    /// The paper's Ex.-2 serving config: d=5, D=300, σ=5, RFF-KLMS μ=1.
    pub fn paper_default() -> Self {
        Self {
            dim: 5,
            features: 300,
            kernel: Kernel::Gaussian { sigma: 5.0 },
            algo: Algo::RffKlms { mu: 1.0 },
            backend: Backend::Native,
        }
    }
}

enum SessionState {
    NativeKlms(RffKlms),
    NativeKrls(RffKrls),
    PjrtKlms {
        map: RffMap,
        omega: Vec<f32>,
        b: Vec<f32>,
        theta: Vec<f32>,
        mu: f32,
        buf_x: Vec<f32>,
        buf_y: Vec<f32>,
        chunk_n: usize,
    },
    PjrtKrls {
        map: RffMap,
        omega: Vec<f32>,
        b: Vec<f32>,
        theta: Vec<f32>,
        p: Vec<f32>,
        beta: f32,
        buf_x: Vec<f32>,
        buf_y: Vec<f32>,
        chunk_n: usize,
    },
}

/// An immutable snapshot of everything a prediction needs: the frozen
/// feature map `(Ω, b)` plus the weight vector θ at snapshot time.
///
/// The service's dynamic batcher takes one of these under the session
/// lock and releases the lock *before* any PJRT dispatch or native
/// per-row predict runs — predictions are then lock-free and trains on
/// the same session proceed concurrently. Taking the snapshot is one
/// `Arc` bump for the map plus a θ copy (2.4 KB at D=300) — far cheaper
/// than holding a lock across a device round-trip.
#[derive(Clone, Debug)]
pub struct PredictState {
    map: Arc<RffMap>,
    theta: Vec<f64>,
}

impl PredictState {
    /// Input dimension d.
    pub fn dim(&self) -> usize {
        self.map.dim()
    }

    /// Feature count D.
    pub fn features(&self) -> usize {
        self.map.features()
    }

    /// The frozen feature map.
    pub fn map(&self) -> &RffMap {
        &self.map
    }

    /// Weight vector θ at snapshot time.
    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    /// θ as f32 (the PJRT artifact input layout).
    pub fn theta_f32(&self) -> Vec<f32> {
        self.theta.iter().map(|&v| v as f32).collect()
    }

    /// `ŷ = θᵀ z_Ω(x)` — same math as [`FilterSession::predict`].
    pub fn predict(&self, x: &[f64]) -> f64 {
        let z = self.map.apply(x);
        crate::linalg::dot(&self.theta, &z)
    }
}

/// One streaming filter session.
pub struct FilterSession {
    config: SessionConfig,
    state: SessionState,
    executor: Option<ExecutorHandle>,
    samples_seen: usize,
    sum_sq_err: f64,
    /// Shared copy of the frozen `(Ω, b)` so [`Self::predict_state`] is
    /// an `Arc` bump under the session lock, not a map memcpy. Costs one
    /// extra map per session (12 KB at d=5, D=300).
    shared_map: Arc<RffMap>,
}

impl FilterSession {
    /// Create a session, drawing the feature map from `rng`.
    /// `executor` is required for [`Backend::Pjrt`].
    pub fn new(
        config: SessionConfig,
        rng: &mut Rng,
        executor: Option<ExecutorHandle>,
    ) -> Result<Self> {
        let map = RffMap::draw(rng, config.kernel, config.dim, config.features);
        Self::with_map(config, map, executor)
    }

    /// Create a session with an explicit feature map (lets tests share
    /// `(Ω, b)` between native and PJRT sessions).
    pub fn with_map(
        config: SessionConfig,
        map: RffMap,
        executor: Option<ExecutorHandle>,
    ) -> Result<Self> {
        let shared_map = Arc::new(map.clone());
        let state = match (config.backend, config.algo) {
            (Backend::Native, Algo::RffKlms { mu }) => {
                SessionState::NativeKlms(RffKlms::new(map, mu))
            }
            (Backend::Native, Algo::RffKrls { beta, lambda }) => {
                SessionState::NativeKrls(RffKrls::new(map, beta, lambda))
            }
            (Backend::Pjrt, algo) => {
                let handle = executor
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("PJRT backend requires an executor"))?;
                let kind = match algo {
                    Algo::RffKlms { .. } => "rffklms_chunk",
                    Algo::RffKrls { .. } => "rffkrls_chunk",
                };
                let chunk_n = handle.chunk_len(kind, config.dim, config.features)?;
                let omega = map.omega_f32_dxD();
                let b = map.phases_f32();
                match algo {
                    Algo::RffKlms { mu } => SessionState::PjrtKlms {
                        theta: vec![0.0; config.features],
                        mu: mu as f32,
                        buf_x: Vec::with_capacity(chunk_n * config.dim),
                        buf_y: Vec::with_capacity(chunk_n),
                        chunk_n,
                        map,
                        omega,
                        b,
                    },
                    Algo::RffKrls { beta, lambda } => {
                        let mut p = vec![0.0f32; config.features * config.features];
                        for i in 0..config.features {
                            p[i * config.features + i] = 1.0 / lambda as f32;
                        }
                        SessionState::PjrtKrls {
                            theta: vec![0.0; config.features],
                            p,
                            beta: beta as f32,
                            buf_x: Vec::with_capacity(chunk_n * config.dim),
                            buf_y: Vec::with_capacity(chunk_n),
                            chunk_n,
                            map,
                            omega,
                            b,
                        }
                    }
                }
            }
        };
        Ok(Self { config, state, executor, samples_seen: 0, sum_sq_err: 0.0, shared_map })
    }

    /// Session configuration.
    pub fn config(&self) -> &SessionConfig {
        &self.config
    }

    /// Samples ingested so far.
    pub fn samples_seen(&self) -> usize {
        self.samples_seen
    }

    /// Running MSE over everything ingested (a-priori errors).
    pub fn running_mse(&self) -> f64 {
        if self.samples_seen == 0 {
            0.0
        } else {
            self.sum_sq_err / self.samples_seen as f64
        }
    }

    /// The feature map.
    pub fn map(&self) -> &RffMap {
        match &self.state {
            SessionState::NativeKlms(f) => f.map(),
            SessionState::NativeKrls(f) => f.map(),
            SessionState::PjrtKlms { map, .. } | SessionState::PjrtKrls { map, .. } => map,
        }
    }

    /// Current weight vector θ (f64 view).
    pub fn theta(&self) -> Vec<f64> {
        match &self.state {
            SessionState::NativeKlms(f) => f.theta().to_vec(),
            SessionState::NativeKrls(f) => f.theta().to_vec(),
            SessionState::PjrtKlms { theta, .. } | SessionState::PjrtKrls { theta, .. } => {
                theta.iter().map(|&v| v as f64).collect()
            }
        }
    }

    /// Snapshot the predict-relevant state `(Ω, b, θ)` — see
    /// [`PredictState`]. Cheap: one `Arc` bump for the frozen map + one
    /// θ copy, no device traffic. Callers (the service batcher) drop the
    /// session lock right after taking this.
    pub fn predict_state(&self) -> PredictState {
        PredictState { map: Arc::clone(&self.shared_map), theta: self.theta() }
    }

    /// Predict `ŷ(x)` with the current model. Single-sample predicts use
    /// the native map even on PJRT sessions (one dispatch per scalar is
    /// never worth it; batched predicts go through the service batcher).
    pub fn predict(&self, x: &[f64]) -> f64 {
        match &self.state {
            SessionState::NativeKlms(f) => f.predict(x),
            SessionState::NativeKrls(f) => f.predict(x),
            SessionState::PjrtKlms { map, theta, .. }
            | SessionState::PjrtKrls { map, theta, .. } => {
                let z = map.apply(x);
                z.iter().zip(theta).map(|(&zi, &t)| zi * t as f64).sum()
            }
        }
    }

    /// Ingest one labelled sample. Native backends return the a-priori
    /// error immediately; the PJRT backend buffers and returns errors in
    /// batches of `chunk_n` (empty vec while the chunk fills).
    pub fn train(&mut self, x: &[f64], y: f64) -> Result<Vec<f64>> {
        anyhow::ensure!(x.len() == self.config.dim, "sample dim mismatch");
        self.samples_seen += 1;
        match &mut self.state {
            SessionState::NativeKlms(f) => {
                let e = f.step(x, y);
                self.sum_sq_err += e * e;
                Ok(vec![e])
            }
            SessionState::NativeKrls(f) => {
                let e = f.step(x, y);
                self.sum_sq_err += e * e;
                Ok(vec![e])
            }
            SessionState::PjrtKlms { buf_x, buf_y, chunk_n, .. } => {
                buf_x.extend(x.iter().map(|&v| v as f32));
                buf_y.push(y as f32);
                if buf_y.len() < *chunk_n {
                    return Ok(Vec::new());
                }
                self.run_klms_chunk()
            }
            SessionState::PjrtKrls { buf_x, buf_y, chunk_n, .. } => {
                buf_x.extend(x.iter().map(|&v| v as f32));
                buf_y.push(y as f32);
                if buf_y.len() < *chunk_n {
                    return Ok(Vec::new());
                }
                self.run_krls_chunk()
            }
        }
    }

    fn run_klms_chunk(&mut self) -> Result<Vec<f64>> {
        let handle = self.executor.as_ref().expect("pjrt session has executor").clone();
        let (d, features) = (self.config.dim, self.config.features);
        let SessionState::PjrtKlms { omega, b, theta, mu, buf_x, buf_y, .. } = &mut self.state
        else {
            unreachable!()
        };
        let (theta_new, errs) = handle.klms_chunk(
            d,
            features,
            std::mem::take(theta),
            std::mem::take(buf_x),
            std::mem::take(buf_y),
            omega.clone(),
            b.clone(),
            *mu,
        )?;
        *theta = theta_new;
        let errs: Vec<f64> = errs.into_iter().map(|e| e as f64).collect();
        self.sum_sq_err += errs.iter().map(|e| e * e).sum::<f64>();
        Ok(errs)
    }

    fn run_krls_chunk(&mut self) -> Result<Vec<f64>> {
        let handle = self.executor.as_ref().expect("pjrt session has executor").clone();
        let (d, features) = (self.config.dim, self.config.features);
        let SessionState::PjrtKrls { omega, b, theta, p, beta, buf_x, buf_y, .. } =
            &mut self.state
        else {
            unreachable!()
        };
        let (theta_new, p_new, errs) = handle.krls_chunk(
            d,
            features,
            std::mem::take(theta),
            std::mem::take(p),
            std::mem::take(buf_x),
            std::mem::take(buf_y),
            omega.clone(),
            b.clone(),
            *beta,
        )?;
        *theta = theta_new;
        *p = p_new;
        let errs: Vec<f64> = errs.into_iter().map(|e| e as f64).collect();
        self.sum_sq_err += errs.iter().map(|e| e * e).sum::<f64>();
        Ok(errs)
    }

    /// Flush a partially filled PJRT chunk by finishing the remainder
    /// with native (mathematically matching) updates. Returns the
    /// remainder's errors. No-op for native sessions.
    pub fn flush(&mut self) -> Result<Vec<f64>> {
        match &mut self.state {
            SessionState::NativeKlms(_) | SessionState::NativeKrls(_) => Ok(Vec::new()),
            SessionState::PjrtKlms { map, theta, mu, buf_x, buf_y, .. } => {
                let d = map.dim();
                let mut errs = Vec::with_capacity(buf_y.len());
                let mut z = vec![0.0f64; theta.len()];
                for (row, &y) in buf_x.chunks(d).zip(buf_y.iter()) {
                    let x: Vec<f64> = row.iter().map(|&v| v as f64).collect();
                    map.apply_into(&x, &mut z);
                    let yhat: f64 = z.iter().zip(theta.iter()).map(|(&zi, &t)| zi * t as f64).sum();
                    let e = y as f64 - yhat;
                    for (t, &zi) in theta.iter_mut().zip(&z) {
                        *t += (*mu as f64 * e * zi) as f32;
                    }
                    errs.push(e);
                }
                buf_x.clear();
                buf_y.clear();
                self.sum_sq_err += errs.iter().map(|e| e * e).sum::<f64>();
                Ok(errs)
            }
            SessionState::PjrtKrls { map, theta, p, beta, buf_x, buf_y, .. } => {
                let d = map.dim();
                let features = theta.len();
                let mut errs = Vec::with_capacity(buf_y.len());
                let mut z = vec![0.0f64; features];
                for (row, &y) in buf_x.chunks(d).zip(buf_y.iter()) {
                    let x: Vec<f64> = row.iter().map(|&v| v as f64).collect();
                    map.apply_into(&x, &mut z);
                    let mut pi = vec![0.0f64; features];
                    for i in 0..features {
                        let prow = &p[i * features..(i + 1) * features];
                        pi[i] = prow.iter().zip(&z).map(|(&pv, &zi)| pv as f64 * zi).sum();
                    }
                    let denom =
                        *beta as f64 + pi.iter().zip(&z).map(|(&a, &b)| a * b).sum::<f64>();
                    let yhat: f64 = z.iter().zip(theta.iter()).map(|(&zi, &t)| zi * t as f64).sum();
                    let e = y as f64 - yhat;
                    let esc = e / denom;
                    for i in 0..features {
                        theta[i] += (pi[i] * esc) as f32;
                    }
                    let inv_beta = 1.0 / *beta as f64;
                    let c = inv_beta / denom;
                    for i in 0..features {
                        let pii = pi[i];
                        let prow = &mut p[i * features..(i + 1) * features];
                        for (j, pv) in prow.iter_mut().enumerate() {
                            *pv = (*pv as f64 * inv_beta - c * pii * pi[j]) as f32;
                        }
                    }
                    errs.push(e);
                }
                buf_x.clear();
                buf_y.clear();
                self.sum_sq_err += errs.iter().map(|e| e * e).sum::<f64>();
                Ok(errs)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::run_rng;
    use crate::signal::{NonlinearWiener, SignalSource};

    #[test]
    fn native_session_learns() {
        let mut rng = run_rng(1, 0);
        let mut s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let mut src = NonlinearWiener::new(run_rng(1, 1), 0.05);
        let mut first = 0.0;
        let mut last = 0.0;
        for (i, smp) in src.take_samples(4000).iter().enumerate() {
            let e = s.train(&smp.x, smp.y).unwrap()[0];
            if i < 200 {
                first += e * e;
            }
            if i >= 3800 {
                last += e * e;
            }
        }
        assert!(last < first * 0.25, "first={first} last={last}");
        assert_eq!(s.samples_seen(), 4000);
        assert!(s.running_mse() > 0.0);
    }

    #[test]
    fn krls_native_session_works() {
        let cfg = SessionConfig {
            algo: Algo::RffKrls { beta: 0.9995, lambda: 1e-4 },
            features: 100,
            ..SessionConfig::paper_default()
        };
        let mut rng = run_rng(2, 0);
        let mut s = FilterSession::new(cfg, &mut rng, None).unwrap();
        let mut src = NonlinearWiener::new(run_rng(2, 1), 0.05);
        for smp in src.take_samples(500) {
            s.train(&smp.x, smp.y).unwrap();
        }
        let mut src2 = NonlinearWiener::new(run_rng(2, 1), 0.05);
        let test = src2.take_samples(600);
        let tail = &test[500..];
        let mse: f64 =
            tail.iter().map(|t| (s.predict(&t.x) - t.clean).powi(2)).sum::<f64>() / 100.0;
        assert!(mse < 0.5, "predict mse {mse}");
    }

    #[test]
    fn pjrt_backend_requires_executor() {
        let cfg = SessionConfig { backend: Backend::Pjrt, ..SessionConfig::paper_default() };
        let mut rng = run_rng(3, 0);
        assert!(FilterSession::new(cfg, &mut rng, None).is_err());
    }

    #[test]
    fn flush_noop_on_native() {
        let mut rng = run_rng(4, 0);
        let mut s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        assert!(s.flush().unwrap().is_empty());
    }

    #[test]
    fn predict_state_matches_live_session() {
        let mut rng = run_rng(6, 0);
        let mut s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        let mut src = NonlinearWiener::new(run_rng(6, 1), 0.05);
        for smp in src.take_samples(500) {
            s.train(&smp.x, smp.y).unwrap();
        }
        let snap = s.predict_state();
        assert_eq!(snap.dim(), 5);
        assert_eq!(snap.features(), 300);
        assert_eq!(snap.theta_f32().len(), 300);
        for smp in src.take_samples(20) {
            assert_eq!(snap.predict(&smp.x), s.predict(&smp.x));
        }
        // the snapshot is detached: further training must not change it
        let frozen = snap.theta().to_vec();
        let probe = src.take_samples(50);
        for smp in &probe {
            s.train(&smp.x, smp.y).unwrap();
        }
        assert_eq!(snap.theta(), &frozen[..]);
        assert_ne!(s.theta(), frozen);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let mut rng = run_rng(5, 0);
        let mut s = FilterSession::new(SessionConfig::paper_default(), &mut rng, None).unwrap();
        assert!(s.train(&[1.0, 2.0], 0.5).is_err());
    }
}
