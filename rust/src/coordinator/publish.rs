//! Lock-free single-writer publication slot (arc-swap shaped, built on
//! `std` only): writers swap in a new `Arc<T>` at train-commit, readers
//! grab the current `Arc<T>` without ever touching a mutex — the predict
//! path's replacement for "lock the session, clone a snapshot".
//!
//! ## Design
//!
//! [`ArcSlot`] holds the current value as a raw `Arc` pointer in an
//! `AtomicPtr`, plus a reader count and a retired-pointer list:
//!
//! * **Readers** ([`ArcSlot::load`]): increment `readers`, load the
//!   pointer, bump its strong count (`Arc::increment_strong_count`),
//!   materialize the `Arc`, decrement `readers`. No locks, no
//!   allocation — two atomic RMWs and a load on the hot path.
//! * **Writers** ([`ArcSlot::store`]): swap the pointer, push the old
//!   pointer onto the retired list, and reclaim the retired list only
//!   when `readers == 0` at that instant. A reader observed mid-flight
//!   defers reclamation to a later `store` (or to `Drop`, which holds
//!   `&mut self` and therefore excludes readers by construction).
//!
//! ## Why the deferred reclamation is sound
//!
//! Every atomic here is `SeqCst`, so all operations order into one
//! total order. Label a reader's ops A (`readers += 1`), B (pointer
//! load), C (strong-count increment), D (`readers -= 1`); a writer's
//! ops E (pointer swap) and F (`readers` check). B returns the retired
//! pointer only if B precedes E in the total order, hence A < B < E < F.
//! When F then reads 0, this reader's D must already have happened
//! (A is visible at F, so only D can make the count 0 again), which
//! means C happened too — the reader already owns a strong reference,
//! and dropping the slot's retired reference cannot free the value. If
//! instead F reads ≥ 1, the writer defers — nothing is freed under the
//! reader. Readers never block writers and vice versa; memory for a
//! superseded value is reclaimed at the first store (or drop) that
//! observes a quiescent instant, so at most O(stores while readers are
//! continuously in flight) values are parked — in the coordinator's use
//! the reader critical section is ~4 instructions, so retirement in
//! practice drains on the next train commit.
//!
//! The payoff for the predict path: `dispatch_predicts` serves batched
//! predictions from the published
//! [`PredictState`](super::PredictState) with **zero** session-mutex
//! acquisitions, so a storm of predicts can never convoy behind a slow
//! train holding the session lock (and vice versa).

use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering::SeqCst};
use std::sync::{Arc, Mutex, PoisonError};

/// A lock-free slot holding an `Arc<T>`: single-value publish/subscribe
/// with wait-free readers (see the module docs for the reclamation
/// protocol).
pub struct ArcSlot<T> {
    /// Current value, as `Arc::into_raw` — the slot owns one strong
    /// reference to it.
    ptr: AtomicPtr<T>,
    /// Readers currently between their `readers += 1` and
    /// `readers -= 1` — while nonzero, retired pointers must not be
    /// reclaimed.
    readers: AtomicUsize,
    /// Superseded pointers awaiting a quiescent instant (each carries
    /// the one strong reference the slot held while it was current).
    retired: Mutex<Vec<*mut T>>,
}

// SAFETY: the raw pointers are `Arc::into_raw` of `Arc<T>`; the slot
// hands out `Arc<T>` clones and drops them, which is exactly as
// Send/Sync as `Arc<T>` itself.
unsafe impl<T: Send + Sync> Send for ArcSlot<T> {}
unsafe impl<T: Send + Sync> Sync for ArcSlot<T> {}

impl<T> ArcSlot<T> {
    /// New slot holding `initial`.
    pub fn new(initial: Arc<T>) -> Self {
        Self {
            ptr: AtomicPtr::new(Arc::into_raw(initial) as *mut T),
            readers: AtomicUsize::new(0),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Grab the currently published value. Wait-free (two atomic RMWs
    /// and a load); never blocks a writer.
    pub fn load(&self) -> Arc<T> {
        self.readers.fetch_add(1, SeqCst); // A
        let p = self.ptr.load(SeqCst); // B
        // SAFETY: `p` came from `Arc::into_raw` and the slot's strong
        // reference to it is still alive: reclamation only happens when
        // a writer reads `readers == 0` *after* swapping the pointer
        // out, and our increment (A) precedes the load (B) in the SeqCst
        // total order — see the module docs for the full argument.
        let arc = unsafe {
            Arc::increment_strong_count(p); // C
            Arc::from_raw(p)
        };
        self.readers.fetch_sub(1, SeqCst); // D
        arc
    }

    /// Publish a new value, retiring the previous one. Retired values
    /// are reclaimed at the first `store` (or `Drop`) that observes no
    /// reader in flight.
    pub fn store(&self, value: Arc<T>) {
        let new = Arc::into_raw(value) as *mut T;
        let old = self.ptr.swap(new, SeqCst); // E
        let mut retired = self.retired.lock().unwrap_or_else(PoisonError::into_inner);
        retired.push(old);
        if self.readers.load(SeqCst) == 0 {
            // F — quiescent: no reader is between A and D, and any
            // reader that saw a retired pointer has already secured its
            // own strong count (module docs), so dropping ours is safe
            for p in retired.drain(..) {
                // SAFETY: each retired pointer carries exactly one
                // strong reference (the one the slot held).
                unsafe { drop(Arc::from_raw(p)) };
            }
        }
    }
}

impl<T> Drop for ArcSlot<T> {
    fn drop(&mut self) {
        // `&mut self` excludes readers and writers, so both the current
        // pointer and every retired pointer can be released.
        let current = *self.ptr.get_mut();
        // SAFETY: the slot holds one strong reference to the current
        // value and one per retired pointer; nothing else can be
        // touching them under `&mut self`.
        unsafe { drop(Arc::from_raw(current)) };
        let retired = self.retired.get_mut().unwrap_or_else(PoisonError::into_inner);
        for p in retired.drain(..) {
            unsafe { drop(Arc::from_raw(p)) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn load_returns_latest_store() {
        let slot = ArcSlot::new(Arc::new(1u64));
        assert_eq!(*slot.load(), 1);
        slot.store(Arc::new(2));
        assert_eq!(*slot.load(), 2);
        for v in 3..100 {
            slot.store(Arc::new(v));
        }
        assert_eq!(*slot.load(), 99);
    }

    #[test]
    fn values_are_dropped_exactly_once() {
        struct Counted(Arc<AtomicUsize>);
        impl Drop for Counted {
            fn drop(&mut self) {
                self.0.fetch_add(1, SeqCst);
            }
        }
        let drops = Arc::new(AtomicUsize::new(0));
        {
            let slot = ArcSlot::new(Arc::new(Counted(Arc::clone(&drops))));
            for _ in 0..10 {
                slot.store(Arc::new(Counted(Arc::clone(&drops))));
            }
            // 10 superseded values reclaimed by quiescent stores
            assert_eq!(drops.load(SeqCst), 10);
            let held = slot.load();
            slot.store(Arc::new(Counted(Arc::clone(&drops))));
            drop(held); // reader's clone outlives retirement safely
        }
        // slot dropped: current + any deferred retirees reclaimed
        assert_eq!(drops.load(SeqCst), 12);
    }

    #[test]
    fn concurrent_readers_and_writer_agree() {
        let slot = Arc::new(ArcSlot::new(Arc::new(0u64)));
        let stop = Arc::new(AtomicBool::new(false));
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let slot = Arc::clone(&slot);
                let stop = Arc::clone(&stop);
                scope.spawn(move || {
                    let mut last = 0u64;
                    while !stop.load(SeqCst) {
                        let v = *slot.load();
                        // published values are monotone: a reader may
                        // lag but never observe a rollback
                        assert!(v >= last, "rollback: {v} < {last}");
                        last = v;
                    }
                });
            }
            for v in 1..=1000u64 {
                slot.store(Arc::new(v));
            }
            stop.store(true, SeqCst);
        });
        assert_eq!(*slot.load(), 1000);
    }
}
