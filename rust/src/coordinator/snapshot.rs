//! Versioned whole-session snapshots and the pluggable spill sinks the
//! session store evicts through.
//!
//! A [`SessionSnapshot`] round-trips a complete
//! [`FilterSession`](super::FilterSession): configuration, feature map
//! (inline or as a registry reference — see
//! [`MapPayload`](crate::kaf::checkpoint::MapPayload)), the learned
//! state of **every** session variant (native f64 θ for KLMS/NLMS,
//! θ+packed-P for KRLS, PJRT f32 θ / θ+P *including any buffered
//! partial chunk rows*, and whole diffusion groups — topology, ordering
//! and per-node θ), and the running stats. The codec guarantees:
//!
//! * **Exactness.** Native f64 state round-trips bit-identically, so
//!   snapshot → restore → train equals the uninterrupted run bitwise
//!   (property-tested in `tests/snapshot_parity.rs`). f32 state is
//!   stored through its exact f64 widening and also round-trips
//!   bitwise.
//! * **Versioning.** Documents carry `"format"` ([`SNAPSHOT_FORMAT`]);
//!   other versions are rejected, never misparsed.
//! * **Fleet-scale maps.** Sessions created from a
//!   [`MapSpec`](crate::kaf::MapSpec) serialize the map as a reference
//!   (config + seed), so a fleet snapshot stores Ω once — in the
//!   registry, not in every document.
//!
//! [`SnapshotSink`] is where evicted sessions spill: [`MemorySink`]
//! (tests, benches, cache-tier semantics) and [`DirSink`] (one JSON file
//! per session, crash-tolerant tmp+rename writes).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Mutex, PoisonError};

use anyhow::{anyhow, bail, Context, Result};

use crate::distributed::DiffusionState;
use crate::kaf::checkpoint::{
    arr, arr_f32, get_arr, get_arr_f32, get_num, get_str, get_usize, kernel_from_json,
    kernel_to_json, MapPayload,
};
use crate::kaf::MapSpec;
use crate::util::json::JsonValue;

use super::session::{Algo, Backend, SessionConfig};

/// Session-snapshot format version written by this build. History:
/// format 1 stored the native-KRLS `P` dense (`"p"`, `[D, D]`
/// row-major); format 2 switched it to the packed upper triangle
/// (`"p_packed"`, `D(D+1)/2` numbers — the filter's live layout);
/// format 3 added two state types — `"native_nlms"` (θ) and
/// `"diffusion"` (a whole group: ordering, topology by canonical edge
/// list, row-major `[nodes, D]` θ); format 4 tags the map payload with
/// its [`MapKind`](crate::kaf::MapKind) (`"kind"`, absent in older
/// documents and defaulted to `"rff"`) and adds the quadrature weight
/// table / adaptive μ_Ω fields — adaptive sessions always serialize
/// their private Ω inline, never as a registry reference.
/// Format-1/2/3 documents are still read (dense P translated at the
/// boundary, missing kind tag defaulted). The PJRT f32 `P` stays dense
/// in every format — that is the device artifact's layout,
/// round-tripped verbatim.
pub const SNAPSHOT_FORMAT: usize = 4;

/// Formats this build can read (see [`SNAPSHOT_FORMAT`]).
pub const SNAPSHOT_READ_FORMATS: [usize; 4] = [1, 2, 3, SNAPSHOT_FORMAT];

/// A serializable snapshot of one filter session's complete state.
///
/// Capture with [`FilterSession::snapshot`](super::FilterSession::snapshot),
/// rebuild with [`FilterSession::restore`](super::FilterSession::restore).
pub struct SessionSnapshot {
    pub(crate) config: SessionConfig,
    pub(crate) map: MapPayload,
    pub(crate) state: SnapshotState,
    pub(crate) samples_seen: usize,
    pub(crate) sum_sq_err: f64,
}

/// Learned state of each `SessionState` variant, decoupled from the live
/// filter objects so the codec has no construction side effects.
pub(crate) enum SnapshotState {
    /// Native f64 RFF-KLMS: θ.
    NativeKlms { theta: Vec<f64> },
    /// Native f64 RFF-KRLS: θ and the packed upper triangle of P
    /// (`D(D+1)/2` floats — the filter's live layout; format-1 dense
    /// documents are translated to this at parse).
    NativeKrls { theta: Vec<f64>, p_packed: Vec<f64> },
    /// Native f64 RFF-NLMS: θ.
    NativeNlms { theta: Vec<f64> },
    /// A diffusion group: ordering, topology (canonical edge list) and
    /// every node's θ — the body codec is shared with the standalone
    /// [`crate::distributed::codec`] documents.
    Diffusion { state: DiffusionState },
    /// PJRT f32 KLMS: θ plus the buffered partial chunk rows.
    PjrtKlms { theta: Vec<f32>, buf_x: Vec<f32>, buf_y: Vec<f32> },
    /// PJRT f32 KRLS: θ, P, and the buffered partial chunk rows.
    PjrtKrls { theta: Vec<f32>, p: Vec<f32>, buf_x: Vec<f32>, buf_y: Vec<f32> },
}

fn algo_to_json(algo: Algo) -> JsonValue {
    let mut obj = BTreeMap::new();
    match algo {
        Algo::RffKlms { mu } => {
            obj.insert("type".into(), JsonValue::String("rffklms".into()));
            obj.insert("mu".into(), JsonValue::Number(mu));
        }
        Algo::RffKrls { beta, lambda } => {
            obj.insert("type".into(), JsonValue::String("rffkrls".into()));
            obj.insert("beta".into(), JsonValue::Number(beta));
            obj.insert("lambda".into(), JsonValue::Number(lambda));
        }
        Algo::RffNlms { mu, eps } => {
            obj.insert("type".into(), JsonValue::String("rffnlms".into()));
            obj.insert("mu".into(), JsonValue::Number(mu));
            obj.insert("eps".into(), JsonValue::Number(eps));
        }
    }
    JsonValue::Object(obj)
}

/// Hyperparameter ranges are checked here at the parse boundary — the
/// filter constructors `assert!` the same bounds, and a corrupt document
/// must be a diagnostic error, never a panic inside a restore (the
/// spill path decodes on router workers).
fn algo_from_json(v: &JsonValue) -> Result<Algo> {
    match get_str(v, "type")? {
        "rffklms" => {
            let mu = get_num(v, "mu")?;
            anyhow::ensure!(mu > 0.0 && mu.is_finite(), "algo mu must be positive");
            Ok(Algo::RffKlms { mu })
        }
        "rffkrls" => {
            let beta = get_num(v, "beta")?;
            let lambda = get_num(v, "lambda")?;
            anyhow::ensure!(beta > 0.0 && beta <= 1.0, "algo beta must be in (0, 1]");
            anyhow::ensure!(
                lambda > 0.0 && lambda.is_finite(),
                "algo lambda must be positive"
            );
            Ok(Algo::RffKrls { beta, lambda })
        }
        "rffnlms" => {
            let mu = get_num(v, "mu")?;
            let eps = get_num(v, "eps")?;
            anyhow::ensure!(mu > 0.0 && mu.is_finite(), "algo mu must be positive");
            anyhow::ensure!(eps >= 0.0 && eps.is_finite(), "algo eps must be non-negative");
            Ok(Algo::RffNlms { mu, eps })
        }
        other => bail!("unknown algo '{other}'"),
    }
}

fn config_to_json(config: &SessionConfig) -> JsonValue {
    let mut obj = BTreeMap::new();
    obj.insert("dim".into(), JsonValue::Number(config.dim as f64));
    obj.insert("features".into(), JsonValue::Number(config.features as f64));
    obj.insert("kernel".into(), kernel_to_json(config.kernel));
    obj.insert("algo".into(), algo_to_json(config.algo));
    let backend = match config.backend {
        Backend::Native => "native",
        Backend::Pjrt => "pjrt",
    };
    obj.insert("backend".into(), JsonValue::String(backend.into()));
    JsonValue::Object(obj)
}

fn config_from_json(v: &JsonValue) -> Result<SessionConfig> {
    let backend = match get_str(v, "backend")? {
        "native" => Backend::Native,
        "pjrt" => Backend::Pjrt,
        other => bail!("unknown backend '{other}'"),
    };
    Ok(SessionConfig {
        dim: get_usize(v, "dim")?,
        features: get_usize(v, "features")?,
        kernel: kernel_from_json(v.get("kernel").ok_or_else(|| anyhow!("missing kernel"))?)?,
        algo: algo_from_json(v.get("algo").ok_or_else(|| anyhow!("missing algo"))?)?,
        backend,
    })
}

impl SessionSnapshot {
    /// Session configuration carried by the snapshot.
    pub fn config(&self) -> &SessionConfig {
        &self.config
    }

    /// Applied-rows count at capture time.
    pub fn samples_seen(&self) -> usize {
        self.samples_seen
    }

    /// The map spec, when the map is stored by reference.
    pub fn map_spec(&self) -> Option<MapSpec> {
        self.map.spec()
    }

    /// Serialize to the versioned JSON document.
    pub fn to_json(&self) -> String {
        let mut state = BTreeMap::new();
        match &self.state {
            SnapshotState::NativeKlms { theta } => {
                state.insert("type".into(), JsonValue::String("native_klms".into()));
                state.insert("theta".into(), arr(theta.iter().copied()));
            }
            SnapshotState::NativeKrls { theta, p_packed } => {
                state.insert("type".into(), JsonValue::String("native_krls".into()));
                state.insert("theta".into(), arr(theta.iter().copied()));
                state.insert("p_packed".into(), arr(p_packed.iter().copied()));
            }
            SnapshotState::NativeNlms { theta } => {
                state.insert("type".into(), JsonValue::String("native_nlms".into()));
                state.insert("theta".into(), arr(theta.iter().copied()));
            }
            SnapshotState::Diffusion { state: body } => {
                state.insert("type".into(), JsonValue::String("diffusion".into()));
                body.write_fields(&mut state);
            }
            SnapshotState::PjrtKlms { theta, buf_x, buf_y } => {
                state.insert("type".into(), JsonValue::String("pjrt_klms".into()));
                state.insert("theta".into(), arr_f32(theta));
                state.insert("buf_x".into(), arr_f32(buf_x));
                state.insert("buf_y".into(), arr_f32(buf_y));
            }
            SnapshotState::PjrtKrls { theta, p, buf_x, buf_y } => {
                state.insert("type".into(), JsonValue::String("pjrt_krls".into()));
                state.insert("theta".into(), arr_f32(theta));
                state.insert("p".into(), arr_f32(p));
                state.insert("buf_x".into(), arr_f32(buf_x));
                state.insert("buf_y".into(), arr_f32(buf_y));
            }
        }
        let mut obj = BTreeMap::new();
        obj.insert("format".into(), JsonValue::Number(SNAPSHOT_FORMAT as f64));
        obj.insert("config".into(), config_to_json(&self.config));
        obj.insert("map".into(), self.map.to_json());
        obj.insert("state".into(), JsonValue::Object(state));
        obj.insert("samples_seen".into(), JsonValue::Number(self.samples_seen as f64));
        obj.insert("sum_sq_err".into(), JsonValue::Number(self.sum_sq_err));
        JsonValue::Object(obj).to_string_compact()
    }

    /// Parse and shape-check a snapshot document. The map is *not*
    /// resolved here — [`FilterSession::restore`](super::FilterSession::restore)
    /// resolves references through the registry it is given.
    pub fn from_json(text: &str) -> Result<Self> {
        let v = JsonValue::parse(text).context("parsing session snapshot")?;
        match v.get("format").and_then(|f| f.as_usize()) {
            Some(f) if SNAPSHOT_READ_FORMATS.contains(&f) => {}
            Some(other) => bail!(
                "unsupported snapshot format {other} \
                 (this build reads formats {SNAPSHOT_READ_FORMATS:?})"
            ),
            None => bail!("session snapshot has no format field"),
        }
        let config =
            config_from_json(v.get("config").ok_or_else(|| anyhow!("missing config"))?)?;
        let map = MapPayload::from_json(v.get("map").ok_or_else(|| anyhow!("missing map"))?)?;
        let sv = v.get("state").ok_or_else(|| anyhow!("missing state"))?;
        let (d, feats) = (config.dim, config.features);
        anyhow::ensure!(d > 0 && feats > 0, "invalid config shape");
        let state = match get_str(sv, "type")? {
            "native_klms" => SnapshotState::NativeKlms { theta: get_arr(sv, "theta")? },
            "native_krls" => {
                // packed (format 2) or dense (format 1, translated here)
                let p_packed = if sv.get("p_packed").is_some() {
                    get_arr(sv, "p_packed")?
                } else {
                    let p = get_arr(sv, "p")?;
                    anyhow::ensure!(
                        p.len() == feats * feats,
                        "dense P shape does not match features"
                    );
                    crate::linalg::simd::pack_upper(feats, &p)
                };
                SnapshotState::NativeKrls { theta: get_arr(sv, "theta")?, p_packed }
            }
            "native_nlms" => SnapshotState::NativeNlms { theta: get_arr(sv, "theta")? },
            "diffusion" => SnapshotState::Diffusion { state: DiffusionState::parse_fields(sv)? },
            "pjrt_klms" => SnapshotState::PjrtKlms {
                theta: get_arr_f32(sv, "theta")?,
                buf_x: get_arr_f32(sv, "buf_x")?,
                buf_y: get_arr_f32(sv, "buf_y")?,
            },
            "pjrt_krls" => SnapshotState::PjrtKrls {
                theta: get_arr_f32(sv, "theta")?,
                p: get_arr_f32(sv, "p")?,
                buf_x: get_arr_f32(sv, "buf_x")?,
                buf_y: get_arr_f32(sv, "buf_y")?,
            },
            other => bail!("unknown snapshot state type '{other}'"),
        };
        // shape checks up front, so a corrupt document errors here rather
        // than panicking inside a filter constructor during restore
        // expected P length differs by variant: native carries the
        // packed triangle, PJRT carries the dense device layout
        let (theta_len, p_check, buf) = match &state {
            SnapshotState::NativeKlms { theta } => (theta.len(), None, None),
            SnapshotState::NativeNlms { theta } => (theta.len(), None, None),
            SnapshotState::Diffusion { state } => {
                // the group's θ payload is [nodes, D]; a node-count /
                // topology mismatch must be a diagnostic error here, not
                // a misparse (edge validity is checked when the topology
                // is rebuilt at restore — also a diagnostic error).
                // One source of truth: the shared body codec's check.
                state.validate(feats)?;
                (feats, None, None) // per-node θ length checked above
            }
            SnapshotState::NativeKrls { theta, p_packed } => {
                let want = crate::linalg::simd::packed_len(feats);
                (theta.len(), Some((p_packed.len(), want)), None)
            }
            SnapshotState::PjrtKlms { theta, buf_x, buf_y } => {
                (theta.len(), None, Some((buf_x.len(), buf_y.len())))
            }
            SnapshotState::PjrtKrls { theta, p, buf_x, buf_y } => (
                theta.len(),
                Some((p.len(), feats * feats)),
                Some((buf_x.len(), buf_y.len())),
            ),
        };
        anyhow::ensure!(theta_len == feats, "theta length does not match features");
        if let Some((p_len, want)) = p_check {
            anyhow::ensure!(p_len == want, "P shape does not match features");
        }
        if let Some((bx, by)) = buf {
            anyhow::ensure!(bx == by * d, "buffered chunk rows are not [n, dim]");
        }
        let samples_seen = get_usize(&v, "samples_seen")?;
        let sum_sq_err = get_num(&v, "sum_sq_err")?;
        Ok(Self { config, map, state, samples_seen, sum_sq_err })
    }
}

// ---- spill sinks --------------------------------------------------------

/// Where evicted sessions spill to, and restore from. Implementations
/// must be safe for concurrent use from multiple router workers; the
/// store serializes same-id accesses itself (per-shard locks), so a sink
/// only needs whole-call atomicity per operation.
pub trait SnapshotSink: Send + Sync {
    /// Persist `snapshot` as the spilled state of session `id`,
    /// overwriting any previous spill of the same id.
    fn put(&self, id: u64, snapshot: &str) -> Result<()>;

    /// Fetch the spilled snapshot of `id` (`None` when not spilled).
    fn get(&self, id: u64) -> Result<Option<String>>;

    /// Drop the spilled snapshot of `id` (no-op when absent).
    fn delete(&self, id: u64) -> Result<()>;

    /// Number of sessions currently spilled.
    fn count(&self) -> usize;
}

/// Bounded-retry wrapper around [`SnapshotSink::put`] — the spill
/// path's write valve. A transient sink failure (busy disk, momentary
/// backend hiccup) retries with the store's yield-then-sleep backoff
/// escalation instead of immediately abandoning the eviction; only a
/// sink that fails every attempt surfaces the error (the store then
/// re-admits the session and counts an `eviction_failure`, as before).
pub(crate) fn put_with_retry(sink: &dyn SnapshotSink, id: u64, snapshot: &str) -> Result<()> {
    /// Retries after the first attempt (4 attempts total).
    const PUT_RETRIES: u32 = 3;
    let mut last = None;
    for attempt in 0..=PUT_RETRIES {
        match sink.put(id, snapshot) {
            Ok(()) => return Ok(()),
            Err(e) => {
                last = Some(e);
                if attempt < PUT_RETRIES {
                    if attempt == 0 {
                        std::thread::yield_now();
                    } else {
                        std::thread::sleep(std::time::Duration::from_micros(100 << attempt));
                    }
                }
            }
        }
    }
    Err(last.expect("at least one attempt ran"))
}

/// In-memory sink: spilled sessions stay in RAM but in *serialized* form
/// — a cache-tier demotion (θ-sized JSON instead of live filter state +
/// lock + map handles). The default sink when no
/// `snapshot_dir` is configured; also what tests and benches use.
#[derive(Debug, Default)]
pub struct MemorySink {
    snapshots: Mutex<BTreeMap<u64, String>>,
}

impl MemorySink {
    /// Empty sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// Total serialized bytes currently held (the spilled-tier footprint).
    pub fn bytes(&self) -> usize {
        self.snapshots
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .values()
            .map(|s| s.len())
            .sum()
    }
}

impl SnapshotSink for MemorySink {
    fn put(&self, id: u64, snapshot: &str) -> Result<()> {
        self.snapshots
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .insert(id, snapshot.to_string());
        Ok(())
    }

    fn get(&self, id: u64) -> Result<Option<String>> {
        Ok(self
            .snapshots
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .get(&id)
            .cloned())
    }

    fn delete(&self, id: u64) -> Result<()> {
        self.snapshots.lock().unwrap_or_else(PoisonError::into_inner).remove(&id);
        Ok(())
    }

    fn count(&self) -> usize {
        self.snapshots.lock().unwrap_or_else(PoisonError::into_inner).len()
    }
}

/// On-disk sink: one `session-<id>.json` per spilled session under a
/// directory. Writes go through a `.tmp` sibling and an atomic rename,
/// so a crash mid-spill leaves either the old snapshot or none — never a
/// torn file that restore would misparse.
#[derive(Debug)]
pub struct DirSink {
    dir: PathBuf,
}

impl DirSink {
    /// Sink rooted at `dir` (created on first spill).
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into() }
    }

    /// The sink's directory.
    pub fn dir(&self) -> &std::path::Path {
        &self.dir
    }

    fn path(&self, id: u64) -> PathBuf {
        // zero-padded so lexicographic directory order is id order
        self.dir.join(format!("session-{id:020}.json"))
    }
}

impl SnapshotSink for DirSink {
    fn put(&self, id: u64, snapshot: &str) -> Result<()> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating snapshot dir {}", self.dir.display()))?;
        let path = self.path(id);
        let tmp = path.with_extension("json.tmp");
        // write + fsync the temp file *before* the rename publishes it:
        // rename is atomic in the namespace, but renaming an unsynced
        // file can surface an empty/torn "snapshot" after power loss —
        // exactly the torn-file class the tmp+rename dance exists to
        // prevent
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            std::io::Write::write_all(&mut f, snapshot.as_bytes())
                .with_context(|| format!("writing {}", tmp.display()))?;
            f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publishing {}", path.display()))?;
        Ok(())
    }

    fn get(&self, id: u64) -> Result<Option<String>> {
        match std::fs::read_to_string(self.path(id)) {
            Ok(text) => Ok(Some(text)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e).with_context(|| format!("reading snapshot of session {id}")),
        }
    }

    fn delete(&self, id: u64) -> Result<()> {
        match std::fs::remove_file(self.path(id)) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e).with_context(|| format!("deleting snapshot of session {id}")),
        }
    }

    fn count(&self) -> usize {
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return 0; // not created yet ⇒ nothing spilled
        };
        entries
            .flatten()
            .filter(|e| {
                let name = e.file_name();
                let name = name.to_string_lossy();
                name.starts_with("session-") && name.ends_with(".json")
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FilterSession;
    use crate::rng::run_rng;

    #[test]
    fn native_krls_snapshot_is_packed_and_reads_legacy_dense() {
        // format coverage for the packed-P layout at the session level:
        // this build writes `p_packed`; a hand-built format-1 dense
        // document restores to the bitwise-identical session
        let feats = 9;
        let cfg = SessionConfig {
            algo: Algo::RffKrls { beta: 0.999, lambda: 1e-3 },
            features: feats,
            ..SessionConfig::paper_default()
        };
        let mut rng = run_rng(21, 0);
        let mut s = FilterSession::new(cfg, &mut rng, None).unwrap();
        for i in 0..50 {
            let t = i as f64 * 0.21;
            let x = [t.sin(), (t * 0.7).cos(), t.cos(), (t * 1.3).sin(), 0.3 * t.sin()];
            s.train(&x, (t * 0.9).cos()).unwrap();
        }
        let text = s.snapshot().to_json();
        assert!(text.contains("\"p_packed\""));
        let packed_restored =
            FilterSession::restore(SessionSnapshot::from_json(&text).unwrap(), None, None)
                .unwrap();
        assert_eq!(packed_restored.theta(), s.theta());

        // rebuild the document in the legacy format-1 dense layout
        let mut v = JsonValue::parse(&text).unwrap();
        let JsonValue::Object(obj) = &mut v else { unreachable!("snapshot is an object") };
        obj.insert("format".into(), JsonValue::Number(1.0));
        let Some(JsonValue::Object(st)) = obj.get_mut("state") else {
            unreachable!("state is an object")
        };
        let packed: Vec<f64> = st
            .remove("p_packed")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        let dense = crate::linalg::simd::unpack_symmetric(feats, &packed);
        st.insert("p".into(), arr(dense.iter().copied()));
        let legacy = v.to_string_compact();
        let snap = SessionSnapshot::from_json(&legacy).expect("legacy dense snapshot reads");
        let restored = FilterSession::restore(snap, None, None).unwrap();
        assert_eq!(restored.theta(), s.theta());
        // identical continuation: the boundary translation was exact
        let probe = [0.2, -0.1, 0.4, 0.0, -0.3];
        assert_eq!(restored.predict(&probe), s.predict(&probe));
        let mut a = s;
        let mut b = restored;
        for i in 0..20 {
            let t = i as f64 * 0.37;
            let x = [t.cos(), t.sin(), 0.5 * t.cos(), (t * 2.0).sin(), 0.1];
            let ea = a.train(&x, t.sin()).unwrap();
            let eb = b.train(&x, t.sin()).unwrap();
            assert_eq!(ea, eb, "continuation diverged after legacy restore");
        }
    }

    #[test]
    fn format3_session_snapshot_without_kind_tag_restores_bitwise() {
        // a pre-family (format-3) document — no map "kind" anywhere —
        // must restore to the bitwise-identical StaticRff session
        let cfg = SessionConfig::paper_default();
        let mut rng = run_rng(31, 0);
        let mut s = FilterSession::new(cfg, &mut rng, None).unwrap();
        for i in 0..60 {
            let t = i as f64 * 0.17;
            let x = [t.sin(), t.cos(), (t * 0.5).sin(), (t * 1.1).cos(), 0.2];
            s.train(&x, (t * 0.8).sin()).unwrap();
        }
        let text = s.snapshot().to_json();
        assert!(text.contains("\"kind\":\"rff\""), "format 4 tags the map kind");
        let mut v = JsonValue::parse(&text).unwrap();
        let JsonValue::Object(obj) = &mut v else { unreachable!("snapshot is an object") };
        obj.insert("format".into(), JsonValue::Number(3.0));
        let Some(JsonValue::Object(map)) = obj.get_mut("map") else {
            unreachable!("map is an object")
        };
        map.remove("kind").expect("kind tag present before stripping");
        let legacy = v.to_string_compact();
        let snap = SessionSnapshot::from_json(&legacy).expect("format-3 snapshot reads");
        let mut restored = FilterSession::restore(snap, None, None).unwrap();
        assert_eq!(restored.theta(), s.theta());
        let mut a = s;
        for i in 0..20 {
            let t = i as f64 * 0.29;
            let x = [t.cos(), t.sin(), 0.4 * t.cos(), (t * 1.7).sin(), -0.1];
            assert_eq!(
                a.train(&x, t.cos()).unwrap(),
                restored.train(&x, t.cos()).unwrap(),
                "continuation diverged after format-3 restore"
            );
        }
    }

    #[test]
    fn unknown_map_kind_in_snapshot_is_diagnostic() {
        let cfg = SessionConfig::paper_default();
        let mut rng = run_rng(32, 0);
        let s = FilterSession::new(cfg, &mut rng, None).unwrap();
        let text = s.snapshot().to_json();
        let doc = text.replace("\"kind\":\"rff\"", "\"kind\":\"spline\"");
        let err = SessionSnapshot::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("unknown map kind 'spline'"), "unhelpful error: {err}");
    }

    #[test]
    fn diffusion_session_snapshot_mismatch_is_diagnostic() {
        // a group snapshot whose node count disagrees with the θ payload
        // must fail parsing with a descriptive error, not misparse
        let registry = crate::kaf::MapRegistry::new();
        let cfg = crate::coordinator::DiffusionGroupConfig {
            session: SessionConfig { features: 8, ..SessionConfig::paper_default() },
            ordering: crate::distributed::DiffusionOrdering::CombineThenAdapt,
            topology: crate::distributed::NetworkTopology::ring(4),
        };
        let s = FilterSession::diffusion_from_spec(cfg, 5, &registry).unwrap();
        let text = s.snapshot().to_json();
        // sanity: the untampered document round-trips
        assert!(SessionSnapshot::from_json(&text).is_ok());
        let mut v = JsonValue::parse(&text).unwrap();
        let JsonValue::Object(obj) = &mut v else { unreachable!("snapshot is an object") };
        let Some(JsonValue::Object(st)) = obj.get_mut("state") else {
            unreachable!("state is an object")
        };
        st.insert("nodes".into(), JsonValue::Number(5.0));
        let err = SessionSnapshot::from_json(&v.to_string_compact())
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("node count and topology disagree"),
            "unhelpful error: {err}"
        );
    }

    #[test]
    fn memory_sink_roundtrip() {
        let sink = MemorySink::new();
        assert_eq!(sink.count(), 0);
        assert_eq!(sink.get(1).unwrap(), None);
        sink.put(1, "alpha").unwrap();
        sink.put(2, "beta").unwrap();
        sink.put(1, "alpha2").unwrap(); // overwrite
        assert_eq!(sink.count(), 2);
        assert_eq!(sink.get(1).unwrap().as_deref(), Some("alpha2"));
        assert_eq!(sink.bytes(), "alpha2".len() + "beta".len());
        sink.delete(1).unwrap();
        sink.delete(1).unwrap(); // idempotent
        assert_eq!(sink.count(), 1);
    }

    #[test]
    fn dir_sink_roundtrip() {
        let dir = std::env::temp_dir().join("rffkaf_dirsink_test");
        let _ = std::fs::remove_dir_all(&dir);
        let sink = DirSink::new(&dir);
        assert_eq!(sink.count(), 0); // dir not created yet
        assert_eq!(sink.get(7).unwrap(), None);
        sink.put(7, "{\"x\":1}").unwrap();
        sink.put(9, "{\"y\":2}").unwrap();
        assert_eq!(sink.count(), 2);
        assert_eq!(sink.get(7).unwrap().as_deref(), Some("{\"x\":1}"));
        sink.delete(7).unwrap();
        sink.delete(7).unwrap();
        assert_eq!(sink.count(), 1);
        assert_eq!(sink.get(7).unwrap(), None);
        // no stray tmp files after a successful publish
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(stray.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn put_with_retry_absorbs_transient_failures() {
        // sink fails twice then recovers: the bounded-backoff retry must
        // land the write on the third attempt without surfacing an error
        let sink = crate::daemon::fault::FlakySink::failing_puts(2);
        put_with_retry(&sink, 5, "{\"v\":1}").unwrap();
        assert_eq!(sink.put_attempts(), 3);
        assert_eq!(sink.get(5).unwrap().as_deref(), Some("{\"v\":1}"));
    }

    #[test]
    fn put_with_retry_gives_up_after_budget() {
        // a sink that fails every attempt must surface the last error
        // after exactly 1 + PUT_RETRIES attempts, not retry forever
        let sink = crate::daemon::fault::FlakySink::failing_puts(100);
        let err = put_with_retry(&sink, 5, "{}").unwrap_err();
        assert!(err.to_string().contains("injected"), "unexpected error: {err}");
        assert_eq!(sink.put_attempts(), 4);
        assert_eq!(sink.count(), 0);
    }
}
