//! Quickstart: learn a nonlinear system online with RFF-KLMS in ~20
//! lines — the paper's §4 algorithm through the public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use rff_kaf::kaf::kernels::Kernel;
use rff_kaf::kaf::{OnlineRegressor, RffKlms, RffMap};
use rff_kaf::metrics::to_db;
use rff_kaf::rng::run_rng;
use rff_kaf::signal::{NonlinearWiener, SignalSource};

fn main() {
    // 1. A nonlinear streaming system: y = w0'x + 0.1 (w1'x)^2 + noise
    //    (the paper's Example 2).
    let mut system = NonlinearWiener::new(run_rng(7, 0), 0.05);

    // 2. Draw the random Fourier feature map for a Gaussian kernel
    //    (sigma = 5) with D = 300 features over d = 5 inputs.
    let mut rng = run_rng(7, 1);
    let map = RffMap::draw(&mut rng, Kernel::Gaussian { sigma: 5.0 }, 5, 300);

    // 3. RFF-KLMS = plain LMS on z_O(x). Fixed-size model: theta in R^300.
    let mut filter = RffKlms::new(map, 1.0);

    // 4. Stream 10k samples; print the learning curve each 1000 steps.
    let mut window = Vec::new();
    for n in 1..=10_000 {
        let s = system.next_sample();
        let e = filter.step(&s.x, s.y);
        window.push(e * e);
        if n % 1000 == 0 {
            let mse: f64 = window.iter().sum::<f64>() / window.len() as f64;
            println!(
                "n={n:>6}  MSE {:>8.2} dB  (model size {} — constant)",
                to_db(mse),
                filter.model_size()
            );
            window.clear();
        }
    }

    // 5. Predict on fresh inputs.
    let probe = system.next_sample();
    println!(
        "\nprediction at fresh x: {:+.4}  (true clean value {:+.4})",
        filter.predict(&probe.x),
        probe.clean
    );
}
