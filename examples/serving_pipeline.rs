//! **End-to-end driver**: boots the full three-layer stack on a real
//! workload and proves all layers compose.
//!
//! * loads the AOT artifacts (L1 Pallas kernel + L2 JAX scan, lowered to
//!   HLO text) into the PJRT executor,
//! * starts the coordinator service with a worker pool and a dynamic
//!   predict batcher,
//! * opens N concurrent filter sessions, each streaming a *different*
//!   nonlinear system through the chunked PJRT training path,
//! * fires batched prediction bursts while training is in flight,
//! * reports per-session steady-state MSE, training throughput, predict
//!   latency percentiles and batcher fill ratio.
//!
//! ```bash
//! make artifacts && cargo run --release --example serving_pipeline
//! # native fallback (no artifacts required):
//! cargo run --release --example serving_pipeline -- --native
//! # ship training rows in batches of 64 (Request::TrainBatch):
//! cargo run --release --example serving_pipeline -- --native --train-batch 64
//! # cap resident sessions (the rest spill/restore through snapshots):
//! cargo run --release --example serving_pipeline -- --native --max-resident 4
//! ```
//!
//! All sessions are registered from one map spec, so the whole fleet
//! shares a single interned `(Ω, b)` — only θ is per-session state.
//!
//! The run recorded in EXPERIMENTS.md §End-to-end used the defaults.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use rff_kaf::coordinator::{
    Backend, CoordinatorService, Request, Response, ServiceConfig, SessionConfig,
};
use rff_kaf::metrics::{to_db, LogHistogram, Stats};
use rff_kaf::rng::run_rng;
use rff_kaf::runtime::PjrtExecutor;
use rff_kaf::signal::{NonlinearWiener, SignalSource};
use rff_kaf::util::Args;

fn main() {
    let args = Args::from_env();
    let n_sessions = args.get_or("sessions", 16usize);
    let n_samples = args.get_or("samples", 1920usize); // 30 chunks of 64
    let native = args.flag("native");
    let seed = args.get_or("seed", 2016u64);
    // rows per Request::TrainBatch; 1 = one Request::Train per row
    let train_batch = args.get_or("train-batch", 1usize).max(1);

    // --- boot the runtime ------------------------------------------------
    let executor = if native {
        None
    } else {
        match PjrtExecutor::start(args.get("dir").unwrap_or("artifacts")) {
            Ok(e) => {
                println!("PJRT platform: {}", e.handle().platform().unwrap());
                Some(e)
            }
            Err(err) => {
                eprintln!("artifacts unavailable ({err}); falling back to native");
                None
            }
        }
    };
    let handle = executor.as_ref().map(|e| e.handle());
    let backend = if handle.is_some() { Backend::Pjrt } else { Backend::Native };
    println!(
        "backend: {backend:?}, {n_sessions} sessions x {n_samples} samples \
         (train batch size {train_batch})"
    );

    // --- boot the coordinator -------------------------------------------
    let workers = args.get_or("workers", 4usize);
    // 0 = unbounded; N caps live sessions, spilling the LRU through
    // versioned snapshots (in-memory sink here; --snapshot-dir for disk)
    let max_resident = args.get_or("max-resident", 0usize);
    let svc = Arc::new(CoordinatorService::start(
        ServiceConfig {
            workers,
            queue_capacity: 2048,
            max_batch: 32,
            batch_wait: std::time::Duration::from_millis(1),
            shards: args.get_or("shards", 16usize),
            max_resident_sessions: max_resident,
            snapshot_dir: args.get("snapshot-dir").map(std::path::PathBuf::from),
            ..ServiceConfig::default()
        },
        handle.clone(),
    ));
    println!(
        "coordinator: {workers} router workers over a {}-shard session store \
         (per-session locking; predicts served from lock-free snapshots{})",
        svc.store().shard_count(),
        if max_resident > 0 {
            format!("; resident cap {max_resident}")
        } else {
            String::new()
        }
    );
    let mut session_ids = Vec::new();
    for _ in 0..n_sessions {
        // one spec for the whole fleet: every session shares the single
        // interned (Ω, b); each still streams its own system below
        let cfg = SessionConfig { backend, ..SessionConfig::paper_default() };
        session_ids.push(svc.add_session_from_spec(cfg, seed).expect("session"));
    }
    println!(
        "fleet: {n_sessions} sessions over {} interned map(s)",
        svc.registry().len()
    );

    // --- training: every session streams its own system ------------------
    let t_train = Instant::now();
    let trainers: Vec<_> = session_ids
        .iter()
        .map(|&sid| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                // each session learns a DIFFERENT system (per-session seed)
                let mut src = NonlinearWiener::new(run_rng(7777, sid as usize), 0.05);
                let mut sum_sq = 0.0;
                let mut count = 0usize;
                let mut tally = |errs: Vec<f64>| {
                    // errors arrive chunk-at-a-time on the PJRT path
                    for e in errs {
                        if count >= n_samples * 3 / 4 {
                            sum_sq += e * e;
                        }
                        count += 1;
                    }
                };
                if train_batch > 1 {
                    // ship rows in row-major [n, d] batches: one queue
                    // slot + one response per batch instead of per row
                    for chunk in src.take_samples(n_samples).chunks(train_batch) {
                        let mut xs = Vec::with_capacity(chunk.len() * 5);
                        let mut ys = Vec::with_capacity(chunk.len());
                        for s in chunk {
                            xs.extend_from_slice(&s.x);
                            ys.push(s.y);
                        }
                        tally(svc.train_batch_sync(sid, xs, ys).expect("train batch"));
                    }
                } else {
                    for s in src.take_samples(n_samples) {
                        tally(svc.train_sync(sid, s.x.clone(), s.y).expect("train"));
                    }
                }
                for e in svc.flush_sync(sid).expect("flush") {
                    sum_sq += e * e;
                    count += 1;
                }
                (sid, sum_sq, count)
            })
        })
        .collect();
    let mut session_mse = Vec::new();
    for t in trainers {
        let (sid, sum_sq, count) = t.join().unwrap();
        let tail = count / 4;
        session_mse.push((sid, sum_sq / tail.max(1) as f64));
    }
    let train_secs = t_train.elapsed().as_secs_f64();
    let total = n_sessions * n_samples;

    // --- serving: batched predict bursts ---------------------------------
    let mut latency = LogHistogram::new();
    let n_bursts = 50;
    let burst = 32;
    let mut probe_src = NonlinearWiener::new(run_rng(8888, 0), 0.05);
    for b in 0..n_bursts {
        let sid = session_ids[b % session_ids.len()];
        let probes = probe_src.take_samples(burst);
        let (tx, rx) = std::sync::mpsc::channel();
        let t0 = Instant::now();
        for p in &probes {
            svc.submit(Request::Predict { session: sid, x: p.x.clone(), resp: tx.clone() })
                .expect("submit");
        }
        drop(tx);
        let mut got = 0;
        while let Ok(r) = rx.recv() {
            match r {
                Response::Predicted(_) => got += 1,
                Response::Error(e) => panic!("predict error: {e}"),
                _ => unreachable!(),
            }
        }
        assert_eq!(got, burst);
        latency.record(t0.elapsed().as_secs_f64());
    }

    // --- report -----------------------------------------------------------
    println!("\n== training ==");
    println!(
        "  {total} samples in {train_secs:.3}s = {:.0} samples/s aggregate",
        total as f64 / train_secs
    );
    let mut mse_stats = Stats::new();
    for &(sid, mse) in &session_mse {
        mse_stats.push(to_db(mse));
        if sid <= 4 {
            println!("  session {sid}: steady-state {:.2} dB", to_db(mse));
        }
    }
    println!(
        "  per-session steady-state MSE: mean {:.2} dB (min {:.2}, max {:.2})",
        mse_stats.mean(),
        mse_stats.min(),
        mse_stats.max()
    );
    println!("\n== serving (bursts of {burst} predicts) ==");
    println!("  {}", latency.report_ms("burst latency"));
    println!(
        "  burst latency: mean {:.3} ms, min {:.3} ms",
        latency.mean() * 1e3,
        latency.min() * 1e3
    );
    let stats = svc.stats();
    let batches = stats.predict_batches.load(Ordering::Relaxed);
    let rows = stats.predict_rows.load(Ordering::Relaxed);
    println!(
        "  trained={} predicted={} errors={} pjrt_batches={} (fill {:.0}%)",
        stats.trained.load(Ordering::Relaxed),
        stats.predicted.load(Ordering::Relaxed),
        stats.errors.load(Ordering::Relaxed),
        batches,
        if batches > 0 { 100.0 * rows as f64 / (batches * 32) as f64 } else { 0.0 },
    );
    if max_resident > 0 {
        println!(
            "  residency: cap {max_resident}, evictions={} restores={} \
             (resident now {}, spilled {})",
            stats.spill.evictions.load(Ordering::Relaxed),
            stats.spill.restores.load(Ordering::Relaxed),
            svc.store().resident_count(),
            svc.store().spilled_count(),
        );
    }
    assert_eq!(stats.errors.load(Ordering::Relaxed), 0, "no request may fail");

    if let Ok(s) = Arc::try_unwrap(svc) {
        s.shutdown();
    }
    println!("\nend-to-end OK: all layers composed.");
}
