//! **Wire front-door driver**: boots a coordinator service + TCP
//! daemon in-process, fires the closed-loop load generator at it over
//! real loopback sockets, and prints client-side throughput/latency
//! next to the server's own `stats`-verb counters (coalescing factor,
//! rejection counts, per-class router latency).
//!
//! ```bash
//! cargo run --release --example wire_loadgen
//! # heavier: 64 connections, 5k rows each, coalescing ablation off:
//! cargo run --release --example wire_loadgen -- \
//!     --connections 64 --rows 5000 --coalesce-off
//! # tune the coalescer:
//! cargo run --release --example wire_loadgen -- --max-batch 128 --flush-us 500
//! # robustness knobs: per-request deadlines, cancel storms, abrupt death:
//! cargo run --release --example wire_loadgen -- --deadline-ms 2 --cancel-rate 16
//! cargo run --release --example wire_loadgen -- --kill-after 500
//! # wire encodings: binary fast path, or train_stream chunking:
//! cargo run --release --example wire_loadgen -- --binary
//! cargo run --release --example wire_loadgen -- --stream --chunk 32
//! ```
//!
//! The run recorded in EXPERIMENTS.md §Wire used `benches/wire.rs`
//! (same loadgen, fixed sweep) — this example is the interactive knob
//! box for exploring one point at a time.

use std::sync::Arc;
use std::time::Duration;

use rff_kaf::coordinator::{CoordinatorService, ServiceConfig, SessionConfig};
use rff_kaf::daemon::loadgen::{run_loadgen, LoadgenConfig, WireClient, WireProtocol};
use rff_kaf::daemon::{CoalesceConfig, Daemon, DaemonConfig};
use rff_kaf::exec::default_parallelism;
use rff_kaf::util::{Args, JsonValue};

fn main() {
    let args = Args::from_env();
    let connections: usize = args.get_or("connections", 8);
    let sessions: usize = args.get_or("sessions", 8);
    let rows: usize = args.get_or("rows", 2000);
    let window: usize = args.get_or("window", 64);
    let features: usize = args.get_or("features", 64);
    let predict_every: usize = args.get_or("predict-every", 5);
    let max_batch: usize = args.get_or("max-batch", 64);
    let flush_us: u64 = args.get_or("flush-us", 200);
    let coalesce_on = !args.flag("coalesce-off");
    // Robustness knobs (ISSUE: deadlines / cancellation / client death).
    let deadline_ms: Option<u64> = args.get("deadline-ms").and_then(|s| s.parse().ok());
    let cancel_every: usize = args.get_or("cancel-rate", 0);
    let kill_after: Option<usize> = args.get("kill-after").and_then(|s| s.parse().ok());
    // Wire encoding (ISSUE: binary fast path / streaming train verb).
    let protocol = if args.flag("stream") {
        WireProtocol::Stream { chunk: args.get_or("chunk", 32) }
    } else if args.flag("binary") {
        WireProtocol::Binary
    } else {
        WireProtocol::Json
    };

    let svc = Arc::new(CoordinatorService::start(
        ServiceConfig {
            workers: default_parallelism().min(8),
            queue_capacity: 4096,
            ..ServiceConfig::default()
        },
        None,
    ));
    let ids: Vec<u64> = (0..sessions)
        .map(|_| {
            let cfg = SessionConfig { features, ..SessionConfig::paper_default() };
            svc.add_session_from_spec(cfg, 7).expect("session spec")
        })
        .collect();
    let daemon = Daemon::start(
        Arc::clone(&svc),
        DaemonConfig {
            max_connections: connections,
            coalesce: CoalesceConfig {
                enabled: coalesce_on,
                max_batch,
                flush_wait: Duration::from_micros(flush_us),
            },
            ..DaemonConfig::default()
        },
    )
    .expect("daemon start");
    let addr = daemon.local_addr();
    let proto_name = match protocol {
        WireProtocol::Json => "json".to_string(),
        WireProtocol::Binary => "binary".to_string(),
        WireProtocol::Stream { chunk } => format!("stream(chunk={chunk})"),
    };
    println!(
        "daemon on {addr}: {connections} connections x {rows} rows, {sessions} sessions, \
         D={features}, proto={proto_name}, coalesce={} (max_batch={max_batch}, flush={flush_us}us)",
        if coalesce_on { "on" } else { "off" },
    );

    let report = run_loadgen(
        addr,
        &LoadgenConfig {
            connections,
            sessions: ids,
            rows_per_connection: rows,
            dim: SessionConfig::paper_default().dim,
            window,
            predict_every,
            seed: 42,
            deadline_ms,
            cancel_every,
            kill_after,
            protocol,
        },
    )
    .expect("loadgen run");

    println!("\n── client side ─────────────────────────────────────────");
    println!("  ok replies    : {}", report.ok_replies);
    println!("  ok rows       : {}", report.ok_rows);
    println!("  rejections    : {}", report.wire_errors);
    println!("  deadline errs : {}", report.deadline_errors);
    println!("  cancel errs   : {}", report.cancel_errors);
    println!("  shed replies  : {}", report.shed_replies);
    println!("  cancel acks   : {}", report.cancel_acks);
    println!("  lost replies  : {}", report.lost_replies);
    println!("  wall clock    : {:.3} s", report.elapsed.as_secs_f64());
    println!("  throughput    : {:.0} rows/s", report.rows_per_sec());
    for (q, tag) in [(0.5, "p50"), (0.95, "p95"), (0.99, "p99")] {
        println!("  latency {tag}   : {:9.1} us", report.latency.quantile(q) * 1e6);
    }

    // server side, over the wire like any other client would see it
    let mut probe = WireClient::connect(addr).expect("stats connection");
    let stats = probe.call_stats().expect("stats verb");
    println!("\n── server side (stats verb) ────────────────────────────");
    for section in ["service", "coalesce", "daemon"] {
        if let Some(JsonValue::Object(fields)) = stats.get(section) {
            for (key, value) in fields {
                if let JsonValue::Number(v) = value {
                    if *v != 0.0 {
                        println!("  {section:8} {key:22}: {v:.0}");
                    }
                }
            }
        }
    }
    if let Some(c) = stats.get("coalesce") {
        let num = |k: &str| c.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        let (rows_in, batches) = (num("train_rows"), num("train_batches"));
        if batches > 0.0 {
            println!("  train coalescing factor: {:.1} rows/batch", rows_in / batches);
        }
    }
    if let Some(JsonValue::Object(classes)) = stats.get("latency") {
        for (class, h) in classes {
            let num = |k: &str| h.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
            if num("count") > 0.0 {
                println!(
                    "  router {class:9}: n={:6.0}  p50={:9.1}us  p99={:9.1}us",
                    num("count"),
                    num("p50_s") * 1e6,
                    num("p99_s") * 1e6,
                );
            }
        }
    }
    drop(probe);

    daemon.shutdown();
    if let Ok(s) = Arc::try_unwrap(svc) {
        s.shutdown();
    }
}
