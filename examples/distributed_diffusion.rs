//! Distributed (diffusion) RFF-KLMS — the §7/[21] extension: a network
//! of nodes cooperatively identifies one nonlinear system, exchanging
//! only fixed-size θ vectors (no dictionaries, no dictionary matching).
//!
//! ```bash
//! cargo run --release --example distributed_diffusion -- --nodes 12 --topology ring
//! ```

use rff_kaf::distributed::{DiffusionRffKlms, NetworkTopology};
use rff_kaf::kaf::kernels::Kernel;
use rff_kaf::kaf::RffMap;
use rff_kaf::metrics::to_db;
use rff_kaf::rng::{run_rng, Distribution, Normal};
use rff_kaf::signal::{NonlinearWiener, SignalSource};
use rff_kaf::util::Args;

fn main() {
    let args = Args::from_env();
    let n_nodes = args.get_or("nodes", 12usize);
    let horizon = args.get_or("samples", 4000usize);
    let topology = args.get("topology").unwrap_or("ring").to_string();

    let topo = match topology.as_str() {
        "ring" => NetworkTopology::ring(n_nodes),
        "complete" => NetworkTopology::complete(n_nodes),
        "random" => {
            let mut rng = run_rng(99, 0);
            NetworkTopology::random(n_nodes, 0.3, &mut rng)
        }
        other => {
            eprintln!("unknown topology {other}; use ring|complete|random");
            std::process::exit(1);
        }
    };
    println!(
        "topology: {topology} ({} nodes, connected: {})",
        topo.len(),
        topo.is_connected()
    );

    // One shared system observed by all nodes with independent noise.
    let mut system = NonlinearWiener::new(run_rng(99, 1), 0.0);
    let mut map_rng = run_rng(99, 2);
    let map = RffMap::draw(&mut map_rng, Kernel::Gaussian { sigma: 5.0 }, 5, 300);
    println!(
        "per-link payload: {} floats (fixed; a dictionary-based filter would ship
  its growing center list every exchange)",
        map.features()
    );

    let mut coop = DiffusionRffKlms::new(topo, map.clone(), 0.5);
    // isolated reference node
    let mut solo = DiffusionRffKlms::new(NetworkTopology::new(1, &[]), map, 0.5);

    let noise = Normal::new(0.0, 0.3);
    let mut noise_rng = run_rng(99, 3);
    let (mut coop_tail, mut solo_tail, mut count) = (0.0, 0.0, 0usize);
    for i in 0..horizon {
        let s = system.next_sample();
        let batch: Vec<(Vec<f64>, f64)> = (0..coop.nodes())
            .map(|_| (s.x.clone(), s.clean + noise.sample(&mut noise_rng)))
            .collect();
        let errs = coop.step(&batch);
        let solo_err = solo.step(&[(s.x.clone(), s.clean + noise.sample(&mut noise_rng))]);
        if i >= horizon - horizon / 4 {
            coop_tail += errs.iter().map(|e| e * e).sum::<f64>() / errs.len() as f64;
            solo_tail += solo_err[0] * solo_err[0];
            count += 1;
        }
        if (i + 1) % (horizon / 8).max(1) == 0 {
            println!(
                "n={:>6}  network disagreement {:.3e}",
                i + 1,
                coop.disagreement()
            );
        }
    }
    let floor = 0.09; // sigma_eta^2
    println!("\nsteady-state MSE (last quarter):");
    println!("  cooperative ({} nodes): {:.2} dB (excess {:.2e})", coop.nodes(), to_db(coop_tail / count as f64), coop_tail / count as f64 - floor);
    println!("  isolated node:          {:.2} dB (excess {:.2e})", to_db(solo_tail / count as f64), solo_tail / count as f64 - floor);
}
