//! Distributed (diffusion) RFF learning — served through the
//! coordinator: a network of nodes cooperatively identifies one
//! nonlinear system, registered as a **diffusion group session**
//! (`Request::TrainDiffusion`), exchanging only fixed-size θ vectors (no
//! dictionaries, no dictionary matching). The isolated baseline is a
//! 1-node group on the same service; both share one interned map.
//!
//! ```bash
//! cargo run --release --example distributed_diffusion -- \
//!     --nodes 12 --topology ring --ordering atc --batch 8
//! ```

use rff_kaf::coordinator::{
    Algo, CoordinatorService, DiffusionGroupConfig, FilterSession, ServiceConfig,
    SessionConfig, SessionSnapshot,
};
use rff_kaf::distributed::{rff_payload_bytes, rff_traffic_bytes, DiffusionOrdering, NetworkTopology};
use rff_kaf::metrics::to_db;
use rff_kaf::rng::{run_rng, Distribution, Normal};
use rff_kaf::signal::{NonlinearWiener, SignalSource};
use rff_kaf::util::Args;

/// Disagreement diagnostic off the *serving* path: snapshot the group
/// through the coordinator's codec and inspect the restored network —
/// the same document the spill/restore machinery moves.
fn group_disagreement(svc: &CoordinatorService, gid: u64) -> f64 {
    let text = svc.snapshot_sync(gid).expect("snapshot");
    let snap = SessionSnapshot::from_json(&text).expect("parse");
    let sess =
        FilterSession::restore(snap, Some(svc.registry().as_ref()), None).expect("restore");
    sess.diffusion().expect("diffusion group").disagreement()
}

fn main() {
    let args = Args::from_env();
    let n_nodes = args.get_or("nodes", 12usize);
    let horizon = args.get_or("samples", 4000usize);
    let batch = args.get_or("batch", 8usize).max(1);
    let topology = args.get("topology").unwrap_or("ring").to_string();
    let ordering = match args.get("ordering").unwrap_or("atc") {
        "atc" => DiffusionOrdering::AdaptThenCombine,
        "cta" => DiffusionOrdering::CombineThenAdapt,
        other => {
            eprintln!("unknown ordering {other}; use atc|cta");
            std::process::exit(1);
        }
    };

    let topo = match topology.as_str() {
        "ring" => NetworkTopology::ring(n_nodes),
        "complete" => NetworkTopology::complete(n_nodes),
        "path" => NetworkTopology::path(n_nodes),
        // random draws surface failure instead of silently substituting
        // another topology (the old ring fallback)
        "random" => match NetworkTopology::random(n_nodes, 0.3, &mut run_rng(99, 0)) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("random topology failed: {e}");
                std::process::exit(1);
            }
        },
        other => {
            eprintln!("unknown topology {other}; use ring|complete|path|random");
            std::process::exit(1);
        }
    };
    let links = topo.links();
    println!(
        "topology: {topology} ({} nodes, {} directed links, connected: {})",
        topo.len(),
        links,
        topo.is_connected()
    );

    // One serving config for the whole fleet: d=5, D=300, KLMS μ=0.5.
    let session = SessionConfig { algo: Algo::RffKlms { mu: 0.5 }, ..SessionConfig::paper_default() };
    let svc = CoordinatorService::start(ServiceConfig::default(), None);
    let gid = svc
        .add_diffusion_group(
            DiffusionGroupConfig { session: session.clone(), ordering, topology: topo },
            2016,
        )
        .expect("register group");
    let solo = svc
        .add_diffusion_group(
            DiffusionGroupConfig {
                session,
                ordering,
                topology: NetworkTopology::new(1, &[]),
            },
            2016,
        )
        .expect("register isolated baseline");
    println!(
        "interned maps: {} — the {n_nodes}-node group and the isolated node share one (Ω, b)",
        svc.registry().len()
    );
    println!(
        "per-link payload: {} B fixed ({} floats; a dictionary-based filter would ship its \
         growing center list every exchange)",
        rff_payload_bytes(300),
        300
    );

    // One shared system observed by all nodes with independent noise;
    // training rides TrainDiffusion windows of `batch` whole rounds.
    let mut system = NonlinearWiener::new(run_rng(99, 1), 0.0);
    let noise = Normal::new(0.0, 0.3);
    let mut noise_rng = run_rng(99, 3);
    let d = 5;
    let tail_from = horizon - horizon / 4;
    let report_every = (horizon / 8).max(1);
    let (mut coop_tail, mut solo_tail, mut count) = (0.0, 0.0, 0usize);
    let mut round = 0usize;
    while round < horizon {
        let window = batch.min(horizon - round);
        let mut xs = Vec::with_capacity(window * n_nodes * d);
        let mut ys = Vec::with_capacity(window * n_nodes);
        let mut solo_xs = Vec::with_capacity(window * d);
        let mut solo_ys = Vec::with_capacity(window);
        for _ in 0..window {
            let s = system.next_sample();
            for _ in 0..n_nodes {
                xs.extend_from_slice(&s.x);
                ys.push(s.clean + noise.sample(&mut noise_rng));
            }
            solo_xs.extend_from_slice(&s.x);
            solo_ys.push(s.clean + noise.sample(&mut noise_rng));
        }
        let errs = svc.train_diffusion_sync(gid, xs, ys).expect("group train");
        let solo_errs = svc.train_diffusion_sync(solo, solo_xs, solo_ys).expect("solo train");
        for w in 0..window {
            if round + w >= tail_from {
                let e = &errs[w * n_nodes..(w + 1) * n_nodes];
                coop_tail += e.iter().map(|e| e * e).sum::<f64>() / n_nodes as f64;
                solo_tail += solo_errs[w] * solo_errs[w];
                count += 1;
            }
        }
        let before = round;
        round += window;
        if round / report_every > before / report_every || round == horizon {
            println!(
                "n={:>6}  network disagreement {:.3e}",
                round,
                group_disagreement(&svc, gid)
            );
        }
    }

    let floor = 0.09; // sigma_eta^2
    println!("\nsteady-state MSE (last quarter):");
    println!(
        "  cooperative ({n_nodes} nodes): {:.2} dB (excess {:.2e})",
        to_db(coop_tail / count as f64),
        coop_tail / count as f64 - floor
    );
    println!(
        "  isolated node:          {:.2} dB (excess {:.2e})",
        to_db(solo_tail / count as f64),
        solo_tail / count as f64 - floor
    );
    println!(
        "cumulative exchange traffic over {horizon} rounds: {:.1} MB \
         (constant per round; see `distributed::traffic` and `cargo bench --bench ablations` \
         for the QKLMS comparison)",
        rff_traffic_bytes(links, 300, horizon) as f64 / 1e6
    );

    // The group is an ordinary session: snapshot it, migrate it under a
    // fresh id, and check the served consensus predictions agree.
    let checkpoint = svc.snapshot_sync(gid).expect("snapshot");
    println!("\ngroup snapshot: {} KB (map by registry reference)", checkpoint.len() / 1024);
    svc.restore_sync(4242, checkpoint).expect("migrate");
    let probe = system.next_sample();
    let a = svc.predict_sync(gid, probe.x.clone()).expect("predict");
    let b = svc.predict_sync(4242, probe.x).expect("predict");
    assert_eq!(a, b, "migrated group must serve identical predictions");
    println!("migrated group serves bitwise-identical consensus predictions ✓");

    let stats = svc.stats();
    println!(
        "service: {} diffusion rows, {} errors",
        stats.diffusion_rows.load(std::sync::atomic::Ordering::Relaxed),
        stats.errors.load(std::sync::atomic::Ordering::Relaxed)
    );
    svc.shutdown();
}
