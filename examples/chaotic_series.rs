//! Chaotic time-series identification (the paper's Examples 3 and 4,
//! Fig. 3): RFF-KLMS vs QKLMS vs Engel's KRLS on both chaotic systems,
//! with dictionary-size accounting.
//!
//! ```bash
//! cargo run --release --example chaotic_series -- --runs 100
//! ```

use rff_kaf::kaf::kernels::Kernel;
use rff_kaf::kaf::{KrlsAld, OnlineRegressor, Qklms, RffKlms, RffMap};
use rff_kaf::metrics::{to_db, LearningCurve};
use rff_kaf::rng::run_rng;
use rff_kaf::signal::{Chaotic1, Chaotic2, SignalSource};
use rff_kaf::util::Args;

fn run_example(
    name: &str,
    runs: usize,
    horizon: usize,
    dim: usize,
    make_source: &dyn Fn(usize) -> Box<dyn SignalSource>,
) {
    let sigma = 0.05;
    let mut curves: Vec<(&str, LearningCurve)> = vec![
        ("QKLMS eps=0.01", LearningCurve::new(horizon)),
        ("RFFKLMS D=100", LearningCurve::new(horizon)),
        ("KRLS-ALD nu=1e-4", LearningCurve::new(horizon)),
    ];
    let mut sizes = [0.0f64; 3];
    for run in 0..runs {
        let samples = make_source(run).take_samples(horizon);
        let mut q = Qklms::new(Kernel::Gaussian { sigma }, dim, 1.0, 0.01);
        curves[0].1.add_run(&q.run(&samples));
        sizes[0] += q.model_size() as f64 / runs as f64;

        let mut rng = run_rng(0xC1A0, run);
        let mut r =
            RffKlms::new(RffMap::draw(&mut rng, Kernel::Gaussian { sigma }, dim, 100), 1.0);
        curves[1].1.add_run(&r.run(&samples));
        sizes[1] += r.model_size() as f64 / runs as f64;

        let mut k = KrlsAld::new(Kernel::Gaussian { sigma }, dim, 1e-4);
        curves[2].1.add_run(&k.run(&samples));
        sizes[2] += k.model_size() as f64 / runs as f64;
    }
    println!("\n=== {name} ({runs} runs x {horizon} samples) ===");
    for ((label, curve), m) in curves.iter().zip(sizes) {
        println!(
            "{label:<18} steady-state {:>8.2} dB   model size {m:.1}",
            to_db(curve.steady_state(horizon / 5))
        );
    }
}

fn main() {
    let args = Args::from_env();
    let runs = args.get_or("runs", 100usize);

    run_example("Example 3 (Fig. 3a)", runs, 500, 1, &|run| {
        Box::new(Chaotic1::paper_default(run_rng(31, run)))
    });
    run_example("Example 4 (Fig. 3b)", runs, 1000, 2, &|run| {
        Box::new(Chaotic2::paper_default(run_rng(32, run)))
    });

    // Beyond the paper: the canonical Mackey-Glass one-step prediction
    // benchmark (embedding order 7), with a wider kernel matched to the
    // attractor's scale.
    mackey_glass_example((runs / 5).max(3));
}

fn mackey_glass_example(runs: usize) {
    use rff_kaf::signal::MackeyGlass;
    let horizon = 2000;
    let (dim, sigma) = (7, 1.0);
    let mut curves: Vec<(&str, LearningCurve)> = vec![
        ("QKLMS eps=1e-4", LearningCurve::new(horizon)),
        ("RFFKLMS D=200", LearningCurve::new(horizon)),
    ];
    let mut sizes = [0.0f64; 2];
    for run in 0..runs {
        let samples = MackeyGlass::chaotic(run_rng(33, run), dim, 0.004).take_samples(horizon);
        let mut q = Qklms::new(Kernel::Gaussian { sigma }, dim, 0.5, 1e-4);
        curves[0].1.add_run(&q.run(&samples));
        sizes[0] += q.model_size() as f64 / runs as f64;
        let mut rng = run_rng(0x4D47, run);
        let mut r =
            RffKlms::new(RffMap::draw(&mut rng, Kernel::Gaussian { sigma }, dim, 200), 0.5);
        curves[1].1.add_run(&r.run(&samples));
        sizes[1] += r.model_size() as f64 / runs as f64;
    }
    println!("\n=== Mackey-Glass one-step prediction ({runs} runs x {horizon}) ===");
    for ((label, curve), m) in curves.iter().zip(sizes) {
        println!(
            "{label:<18} steady-state {:>8.2} dB   model size {m:.1}",
            to_db(curve.steady_state(horizon / 5))
        );
    }
}
