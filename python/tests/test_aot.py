"""AOT emitter checks: the catalogue lowers, HLO text is parseable-looking,
and the manifest agrees with what is on disk (when artifacts are built).
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestCatalogue:
    def test_catalogue_names_unique(self):
        names = [name for name, *_ in aot.catalogue()]
        assert len(names) == len(set(names))
        assert len(names) >= 15

    def test_catalogue_covers_paper_configs(self):
        names = [name for name, *_ in aot.catalogue()]
        # Ex.2 / Fig.1 / Fig.2 config
        assert "rffklms_chunk_d5_D300_N64" in names
        assert "rffkrls_chunk_d5_D300_N64" in names
        # Ex.3 chaotic (d=1) and Ex.4 (d=2)
        assert "rffklms_chunk_d1_D100_N64" in names
        assert "rffklms_chunk_d2_D100_N64" in names

    def test_lower_one_produces_hlo_text(self):
        # Lower the smallest artifact and sanity-check the text format the
        # Rust loader (HloModuleProto::from_text_file) consumes.
        for name, fn, args, meta in aot.catalogue():
            if name == "rff_features_d1_D100_B32":
                text = aot.lower_one(fn, args)
                assert "HloModule" in text
                assert "ENTRY" in text
                # return_tuple=True => root is a tuple
                assert "tuple(" in text or "tuple." in text
                return
        pytest.fail("expected artifact missing from catalogue")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    def _manifest(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_files_exist(self):
        m = self._manifest()
        assert m["format"] == 1
        for a in m["artifacts"]:
            path = os.path.join(ART_DIR, a["file"])
            assert os.path.exists(path), a["file"]
            with open(path) as f:
                head = f.read(64)
            assert "HloModule" in head

    def test_manifest_matches_catalogue(self):
        m = self._manifest()
        disk = {a["name"] for a in m["artifacts"]}
        cat = {name for name, *_ in aot.catalogue()}
        assert disk == cat

    def test_manifest_shapes_recorded(self):
        m = self._manifest()
        for a in m["artifacts"]:
            assert "inputs" in a and "outputs" in a and "kind" in a
            if a["kind"].endswith("chunk"):
                assert a["N"] == m["chunk_n"]
