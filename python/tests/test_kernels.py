"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref.py`).

Hypothesis sweeps shapes; fixed-seed numpy draws the data. The assertions
are tight (1e-5) because both sides compute in f32 on CPU interpret mode.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import rff_features, gauss_kernel
from compile.kernels.rff import _tile_d, vmem_footprint_bytes, mxu_utilization_estimate
from compile.kernels.gauss import _tile_m
from compile.kernels.ref import (
    gauss_kernel_ref,
    rff_features_ref,
    sample_rff_params_ref,
)

RTOL = 1e-5
ATOL = 1e-5


def _data(seed, B, d, D, sigma=5.0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(B, d)).astype(np.float32)
    om, b = sample_rff_params_ref(rng, d, D, sigma)
    return x, om.astype(np.float32), b.astype(np.float32)


class TestRffFeatures:
    @settings(deadline=None, max_examples=20)
    @given(
        B=st.integers(1, 16),
        d=st.integers(1, 8),
        D=st.sampled_from([1, 2, 7, 32, 50, 96, 100, 128, 300]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_shape_sweep(self, B, d, D, seed):
        x, om, b = _data(seed, B, d, D)
        got = np.array(rff_features(jnp.array(x), jnp.array(om), jnp.array(b)))
        want = np.array(rff_features_ref(jnp.array(x), jnp.array(om), jnp.array(b)))
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_output_shape_and_dtype(self):
        x, om, b = _data(0, 4, 5, 64)
        z = rff_features(jnp.array(x), jnp.array(om), jnp.array(b))
        assert z.shape == (4, 64)
        assert z.dtype == jnp.float32

    def test_feature_magnitude_bound(self):
        # |z_i| <= sqrt(2/D) componentwise (it's a scaled cosine).
        x, om, b = _data(1, 8, 3, 50)
        z = np.array(rff_features(jnp.array(x), jnp.array(om), jnp.array(b)))
        assert np.all(np.abs(z) <= np.sqrt(2.0 / 50) + 1e-6)

    def test_kernel_approximation_mc(self):
        # z(x)^T z(y) -> kappa_sigma(x - y) as D grows (Theorem 1 / Eq. (4)).
        rng = np.random.default_rng(7)
        d, D, sigma = 5, 8192, 5.0
        x = rng.normal(size=(2, d)).astype(np.float32)
        om, b = sample_rff_params_ref(rng, d, D, sigma)
        z = np.array(
            rff_features(jnp.array(x), jnp.array(om.astype(np.float32)), jnp.array(b.astype(np.float32)))
        )
        approx = float(z[0] @ z[1])
        exact = float(np.exp(-np.sum((x[0] - x[1]) ** 2) / (2 * sigma**2)))
        # MC error ~ 1/sqrt(D) ~ 0.011; allow 5 sigma.
        assert abs(approx - exact) < 5.0 / np.sqrt(D)

    def test_deterministic(self):
        x, om, b = _data(3, 4, 2, 32)
        z1 = np.array(rff_features(jnp.array(x), jnp.array(om), jnp.array(b)))
        z2 = np.array(rff_features(jnp.array(x), jnp.array(om), jnp.array(b)))
        np.testing.assert_array_equal(z1, z2)

    def test_shift_invariance_of_gram(self):
        # The Gram approximation depends only on x - y: shifting both rows
        # by the same vector leaves z(x)^T z(y) approximately unchanged.
        rng = np.random.default_rng(11)
        d, D = 3, 4096
        x = rng.normal(size=(2, d)).astype(np.float32)
        shift = rng.normal(size=(1, d)).astype(np.float32)
        om, b = sample_rff_params_ref(rng, d, D, 2.0)
        om, b = om.astype(np.float32), b.astype(np.float32)
        z = np.array(rff_features(jnp.array(x), jnp.array(om), jnp.array(b)))
        zs = np.array(rff_features(jnp.array(x + shift), jnp.array(om), jnp.array(b)))
        assert abs(float(z[0] @ z[1]) - float(zs[0] @ zs[1])) < 10.0 / np.sqrt(D)


class TestGaussKernel:
    @settings(deadline=None, max_examples=20)
    @given(
        B=st.integers(1, 12),
        M=st.sampled_from([1, 3, 8, 32, 100, 128]),
        d=st.integers(1, 8),
        sigma=st.sampled_from([0.05, 0.5, 1.0, 5.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_shape_sweep(self, B, M, d, sigma, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(B, d)).astype(np.float32)
        c = rng.normal(size=(M, d)).astype(np.float32)
        got = np.array(gauss_kernel(jnp.array(x), jnp.array(c), sigma=sigma))
        want = np.array(gauss_kernel_ref(jnp.array(x), jnp.array(c), sigma))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_self_kernel_is_one(self):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(6, 4)).astype(np.float32)
        k = np.array(gauss_kernel(jnp.array(x), jnp.array(x), sigma=1.0))
        np.testing.assert_allclose(np.diag(k), 1.0, atol=1e-5)

    def test_range(self):
        rng = np.random.default_rng(1)
        x = rng.normal(size=(5, 3)).astype(np.float32) * 10
        c = rng.normal(size=(7, 3)).astype(np.float32) * 10
        k = np.array(gauss_kernel(jnp.array(x), jnp.array(c), sigma=0.5))
        assert np.all(k >= 0.0) and np.all(k <= 1.0 + 1e-6)


class TestTiling:
    @given(D=st.integers(1, 2048))
    @settings(deadline=None, max_examples=60)
    def test_tile_divides(self, D):
        t = _tile_d(D)
        assert D % t == 0 and 1 <= t <= 128

    @given(M=st.integers(1, 2048))
    @settings(deadline=None, max_examples=60)
    def test_tile_m_divides(self, M):
        t = _tile_m(M)
        assert M % t == 0 and 1 <= t <= 128

    def test_vmem_footprint_under_budget(self):
        # Every catalogued config must fit one grid step well under 16 MiB VMEM.
        for (B, d, D) in [(32, 5, 300), (64, 5, 300), (32, 1, 100), (64, 2, 100)]:
            assert vmem_footprint_bytes(B, d, D) < 16 * 1024 * 1024 / 4

    def test_mxu_estimate_in_range(self):
        u = mxu_utilization_estimate(32, 5, 300)
        assert 0.0 < u <= 1.0
