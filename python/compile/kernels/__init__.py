"""L1 Pallas kernels + pure-jnp reference oracle."""

from .gauss import gauss_kernel
from .rff import mxu_utilization_estimate, rff_features, vmem_footprint_bytes

__all__ = [
    "rff_features",
    "gauss_kernel",
    "vmem_footprint_bytes",
    "mxu_utilization_estimate",
]
