"""L1 Pallas kernel: the random Fourier feature map (paper Eq. (3)).

The hot op of the whole system is

    Z[B, D] = sqrt(2/D) * cos(X[B, d] @ Omega[d, D] + b[D])

i.e. a skinny matmul with a fused bias + cos + scale epilogue. On TPU this
is MXU work: we tile the (B, D) output over the D axis so each grid step
holds an (B, TILE_D) block in VMEM, runs one MXU contraction (d is small,
<= 8 for every paper experiment, so the contraction dimension is untiled),
and fuses the epilogue before the block leaves VMEM — no HBM round-trip
between the matmul and the cos.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated against `ref.py`, TPU performance
is estimated analytically in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Preferred output tile along the feature axis. 128 matches the TPU lane
# width (the MXU is 128x128); for small D we fall back to a divisor of D.
_PREFERRED_TILE_D = 128


def _tile_d(D: int) -> int:
    """Largest divisor of D that is <= _PREFERRED_TILE_D.

    Keeps the grid exact (no padding logic in the kernel body). Every paper
    configuration (D in {50, 100, 300, 500, 1000, ...}) admits a reasonable
    divisor; worst case we degrade to 1-wide tiles but stay correct.
    """
    for t in range(min(D, _PREFERRED_TILE_D), 0, -1):
        if D % t == 0:
            return t
    return 1


def _rff_kernel(x_ref, omega_ref, b_ref, o_ref, *, scale: float):
    """One (B, TILE_D) output block: matmul + fused bias/cos/scale epilogue."""
    # f32 accumulation on the MXU (preferred_element_type pins the
    # accumulator even if inputs were bf16 on a real TPU).
    acc = jnp.dot(x_ref[...], omega_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (scale * jnp.cos(acc + b_ref[...][None, :])).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rff_features(x: jnp.ndarray, omega: jnp.ndarray, b: jnp.ndarray, *, interpret: bool = True) -> jnp.ndarray:
    """Pallas RFF feature map: Z = sqrt(2/D) cos(X @ Omega + b).

    Args:
      x:     [B, d] batch of inputs.
      omega: [d, D] random frequencies.
      b:     [D] random phases.

    Returns: [B, D] feature matrix, same dtype as x.
    """
    B, d = x.shape
    d2, D = omega.shape
    assert d == d2, f"x/omega contraction mismatch: {d} vs {d2}"
    assert b.shape == (D,)
    tile = _tile_d(D)
    grid = (D // tile,)
    scale = float((2.0 / D) ** 0.5)
    return pl.pallas_call(
        functools.partial(_rff_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, d), lambda j: (0, 0)),      # X stays resident
            pl.BlockSpec((d, tile), lambda j: (0, j)),   # Omega streams by tile
            pl.BlockSpec((tile,), lambda j: (j,)),       # phases stream by tile
        ],
        out_specs=pl.BlockSpec((B, tile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((B, D), x.dtype),
        interpret=interpret,
    )(x, omega, b)


def vmem_footprint_bytes(B: int, d: int, D: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grid step (DESIGN.md §Perf).

    X block + Omega tile + b tile + output tile + f32 accumulator.
    """
    tile = _tile_d(D)
    x_blk = B * d * dtype_bytes
    om_blk = d * tile * dtype_bytes
    b_blk = tile * dtype_bytes
    out_blk = B * tile * dtype_bytes
    acc = B * tile * 4
    return x_blk + om_blk + b_blk + out_blk + acc


def mxu_utilization_estimate(B: int, d: int, D: int) -> float:
    """Fraction of MXU 128x128x8 issue slots doing useful work per tile.

    The contraction dim is d (<=8 in all paper configs) against a 128-deep
    systolic array, so utilization is bounded by d/128 on the matmul —
    which is why the fused epilogue (VPU work) dominates and the kernel is
    memory/VPU bound, not MXU bound. Recorded honestly in §Perf.
    """
    tile = _tile_d(D)
    return min(B, 128) / 128.0 * min(tile, 128) / 128.0 * min(d, 128) / 128.0
