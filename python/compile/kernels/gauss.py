"""L1 Pallas kernel: Gaussian kernel (Gram) matrix.

    K[B, M] = exp(-||x_i - c_j||^2 / (2 sigma^2))

Used by the QKLMS baseline cross-check path and by the exact-kernel
comparison experiments (RFF approximation-error ablation). Tiled over the
center axis M; the squared distance is computed via the expansion
||x||^2 + ||c||^2 - 2 x.c so the inner loop is again one MXU matmul with a
fused epilogue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_PREFERRED_TILE_M = 128


def _tile_m(M: int) -> int:
    for t in range(min(M, _PREFERRED_TILE_M), 0, -1):
        if M % t == 0:
            return t
    return 1


def _gauss_kernel(x_ref, c_ref, o_ref, *, inv_two_sigma_sq: float):
    x = x_ref[...]  # [B, d]
    c = c_ref[...]  # [TM, d]
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # [B, 1]
    c2 = jnp.sum(c * c, axis=1)[None, :]  # [1, TM]
    cross = jnp.dot(x, c.T, preferred_element_type=jnp.float32)  # [B, TM]
    d2 = jnp.maximum(x2 + c2 - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-d2 * inv_two_sigma_sq).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sigma", "interpret"))
def gauss_kernel(x: jnp.ndarray, c: jnp.ndarray, *, sigma: float, interpret: bool = True) -> jnp.ndarray:
    """Pallas Gaussian kernel matrix.

    Args:
      x: [B, d] query batch.
      c: [M, d] centers (dictionary).
      sigma: kernel bandwidth (static: baked into the artifact).

    Returns: [B, M] kernel matrix.
    """
    B, d = x.shape
    M, d2 = c.shape
    assert d == d2
    tile = _tile_m(M)
    inv = 1.0 / (2.0 * sigma * sigma)
    return pl.pallas_call(
        functools.partial(_gauss_kernel, inv_two_sigma_sq=inv),
        grid=(M // tile,),
        in_specs=[
            pl.BlockSpec((B, d), lambda j: (0, 0)),
            pl.BlockSpec((tile, d), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((B, tile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((B, M), x.dtype),
        interpret=interpret,
    )(x, c)
