"""Pure-jnp reference oracle for every L1 Pallas kernel.

These are the CORE correctness signal: the Pallas kernels in `rff.py` and
`gauss.py` and the L2 scan models in `model.py` are asserted allclose
against these implementations by `python/tests/`.

All functions are plain jax.numpy — no pallas, no control flow tricks —
so they can be read as the mathematical definition.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def rff_features_ref(x: jnp.ndarray, omega: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Random Fourier feature map, Eq. (3) of the paper.

    z_Omega(u) = sqrt(2/D) * cos(Omega^T u + b), applied row-wise.

    Args:
      x:     [B, d] input batch.
      omega: [d, D] frequency matrix (columns are omega_i ~ N(0, I/sigma^2)).
      b:     [D]    phases (b_i ~ U[0, 2pi]).

    Returns:
      [B, D] feature matrix Z with Z @ Z.T approximating the kernel Gram.
    """
    d, D = omega.shape
    scale = jnp.sqrt(2.0 / D).astype(x.dtype)
    return scale * jnp.cos(x @ omega + b[None, :])


def gauss_kernel_ref(x: jnp.ndarray, c: jnp.ndarray, sigma: float) -> jnp.ndarray:
    """Gaussian kernel matrix K[i, j] = exp(-||x_i - c_j||^2 / (2 sigma^2))."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # [B,1]
    c2 = jnp.sum(c * c, axis=1)[None, :]  # [1,M]
    d2 = jnp.maximum(x2 + c2 - 2.0 * (x @ c.T), 0.0)
    return jnp.exp(-d2 / (2.0 * sigma * sigma))


def rffklms_chunk_ref(theta, x, y, omega, b, mu):
    """Reference RFF-KLMS over an N-sample chunk (numpy loop, float64).

    Per-sample recursion (paper §4):
      e_n     = y_n - theta^T z(x_n)
      theta  += mu * e_n * z(x_n)

    Returns (theta_out [D], errors [N]).
    """
    theta = np.asarray(theta, dtype=np.float64).copy()
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    omega = np.asarray(omega, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    mu = float(np.asarray(mu).reshape(()))
    D = omega.shape[1]
    scale = np.sqrt(2.0 / D)
    errs = np.zeros(x.shape[0])
    for n in range(x.shape[0]):
        z = scale * np.cos(x[n] @ omega + b)
        e = y[n] - theta @ z
        theta = theta + mu * e * z
        errs[n] = e
    return theta, errs


def rffkrls_chunk_ref(theta, p, x, y, omega, b, beta):
    """Reference exponentially-weighted RFF-KRLS over an N-sample chunk.

    Standard RLS on z-features with forgetting factor beta (paper §6):
      z    = z_Omega(x_n)
      pi   = P z
      k    = pi / (beta + z^T pi)
      e    = y_n - theta^T z           (a-priori error)
      theta += k e
      P    = (P - k pi^T) / beta

    P is initialised by the caller to I / lambda (regularisation).
    Returns (theta_out [D], P_out [D,D], errors [N]).
    """
    theta = np.asarray(theta, dtype=np.float64).copy()
    p = np.asarray(p, dtype=np.float64).copy()
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    omega = np.asarray(omega, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    beta = float(np.asarray(beta).reshape(()))
    D = omega.shape[1]
    scale = np.sqrt(2.0 / D)
    errs = np.zeros(x.shape[0])
    for n in range(x.shape[0]):
        z = scale * np.cos(x[n] @ omega + b)
        pi = p @ z
        denom = beta + z @ pi
        k = pi / denom
        e = y[n] - theta @ z
        theta = theta + k * e
        p = (p - np.outer(k, pi)) / beta
        errs[n] = e
    return theta, p, errs


def sample_rff_params_ref(rng: np.random.Generator, d: int, D: int, sigma: float):
    """Draw (omega [d,D], b [D]) for the Gaussian kernel of bandwidth sigma.

    Bochner: p(omega) = N(0, I/sigma^2)  (paper Eq. (5));  b ~ U[0, 2pi].
    """
    omega = rng.normal(0.0, 1.0 / sigma, size=(d, D))
    b = rng.uniform(0.0, 2.0 * np.pi, size=(D,))
    return omega, b
