"""AOT emitter: lower every L2 graph to HLO *text* + write a manifest.

HLO text (NOT `lowered.compiler_ir("hlo")`-proto `.serialize()`): jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
bundled xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The text
parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/README.md and gen_hlo.py.

Usage (from python/):  python -m compile.aot --out ../artifacts

Emits one `<name>.hlo.txt` per (graph, shape-config) pair plus
`manifest.json` describing inputs/outputs so the Rust artifact registry
can type-check calls at load time.

Artifacts are lowered with return_tuple=True: the Rust side unwraps with
`to_tuple()` / `to_tuple1()`.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Artifact catalogue.
#
# Canonical shape configs cover every paper experiment:
#   Ex. 2 / Fig. 1 / Fig. 2:  d=5, D=300 (and D=100 for the Fig. 1 sweep)
#   Ex. 3 (chaotic1):         d=1, D=100  (delay embedding of order 1: u_{n-1})
#   Ex. 4 (chaotic2):         d=2, D=100  (inputs (u_n, v_n))
# Chunk length N=64 amortises PJRT dispatch; batch B=32 for the batcher.
# ---------------------------------------------------------------------------

CHUNK_N = 64
BATCH_B = 32


def catalogue():
    """Yield (name, jitted_fn, example_args, meta) for every artifact."""
    configs = [
        dict(d=5, D=300),
        dict(d=5, D=100),
        dict(d=1, D=100),
        dict(d=2, D=100),
    ]
    for cfg in configs:
        d, D = cfg["d"], cfg["D"]
        n, bsz = CHUNK_N, BATCH_B

        name = f"rffklms_chunk_d{d}_D{D}_N{n}"
        args = (spec(D), spec(n, d), spec(n), spec(d, D), spec(D), spec(1))
        yield name, model.rffklms_chunk, args, dict(
            kind="rffklms_chunk", d=d, D=D, N=n,
            inputs=["theta[D]", "x[N,d]", "y[N]", "omega[d,D]", "b[D]", "mu[1]"],
            outputs=["theta[D]", "errors[N]"],
        )

        name = f"rff_features_d{d}_D{D}_B{bsz}"
        args = (spec(bsz, d), spec(d, D), spec(D))
        yield name, model.rff_features_batch, args, dict(
            kind="rff_features", d=d, D=D, B=bsz,
            inputs=["x[B,d]", "omega[d,D]", "b[D]"],
            outputs=["z[B,D]"],
        )

        name = f"rff_predict_d{d}_D{D}_B{bsz}"
        args = (spec(D), spec(bsz, d), spec(d, D), spec(D))
        yield name, model.rff_predict_batch, args, dict(
            kind="rff_predict", d=d, D=D, B=bsz,
            inputs=["theta[D]", "x[B,d]", "omega[d,D]", "b[D]"],
            outputs=["yhat[B]"],
        )

    # KRLS chunk only for the Fig. 2b config (P is D^2 — keep D moderate).
    for d, D in [(5, 300), (1, 100)]:
        n = CHUNK_N
        name = f"rffkrls_chunk_d{d}_D{D}_N{n}"
        args = (spec(D), spec(D, D), spec(n, d), spec(n), spec(d, D), spec(D), spec(1))
        yield name, model.rffkrls_chunk, args, dict(
            kind="rffkrls_chunk", d=d, D=D, N=n,
            inputs=["theta[D]", "p[D,D]", "x[N,d]", "y[N]", "omega[d,D]", "b[D]", "beta[1]"],
            outputs=["theta[D]", "p[D,D]", "errors[N]"],
        )

    # Gaussian Gram block for the QKLMS cross-check (sigma baked in).
    for d, M, sigma in [(5, 128, 5.0), (1, 32, 0.05), (2, 32, 0.05)]:
        name = f"gauss_kernel_d{d}_M{M}"
        fn = functools.partial(model.gauss_kernel_batch, sigma=sigma)
        args = (spec(BATCH_B, d), spec(M, d))
        yield name, fn, args, dict(
            kind="gauss_kernel", d=d, M=M, B=BATCH_B, sigma=sigma,
            inputs=["x[B,d]", "c[M,d]"],
            outputs=["k[B,M]"],
        )


def lower_one(fn, args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ns = ap.parse_args()
    os.makedirs(ns.out, exist_ok=True)

    manifest = {"format": 1, "chunk_n": CHUNK_N, "batch_b": BATCH_B, "artifacts": []}
    for name, fn, args, meta in catalogue():
        if ns.only and ns.only not in name:
            continue
        text = lower_one(fn, args)
        path = os.path.join(ns.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry = dict(name=name, file=f"{name}.hlo.txt", **meta)
        manifest["artifacts"].append(entry)
        print(f"  {name}: {len(text)} chars")

    with open(os.path.join(ns.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {ns.out}")


if __name__ == "__main__":
    main()
