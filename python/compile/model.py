"""L2 JAX compute graphs: chunked online-filter updates built on the L1
Pallas kernels.

The online recursions (KLMS / RLS) are sequential per sample, but the
feature map z_Omega(x_n) does NOT depend on the filter state theta — so a
chunk of N samples is processed as

    1. one Pallas call  Z[N, D] = rff_features(X[N, d])     (MXU work)
    2. a lax.scan over the rows of Z for the cheap recursion (VPU work)

This is mathematically identical to the per-sample algorithm in the paper
(§4 / §6) and is what makes the AOT artifact coarse enough for the Rust
coordinator to amortise PJRT dispatch over N samples.

Every function here is lowered once by `aot.py` to HLO text; Python never
runs on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import gauss_kernel, rff_features


def rffklms_chunk(theta, x, y, omega, b, mu):
    """RFF-KLMS over an N-sample chunk (paper §4).

    Args:
      theta: [D]    current weight vector.
      x:     [N, d] chunk of inputs.
      y:     [N]    chunk of targets.
      omega: [d, D] RFF frequencies.
      b:     [D]    RFF phases.
      mu:    [1]    step size (runtime input so one artifact covers all mu).

    Returns:
      theta_out: [D]  updated weights.
      errors:    [N]  a-priori errors e_n = y_n - theta_n^T z(x_n).
    """
    z = rff_features(x, omega, b)  # [N, D] — the L1 Pallas kernel
    mu_s = mu[0]

    def step(th, inp):
        zn, yn = inp
        e = yn - jnp.dot(th, zn)
        return th + mu_s * e * zn, e

    theta_out, errors = lax.scan(step, theta, (z, y))
    return theta_out, errors


def rffkrls_chunk(theta, p, x, y, omega, b, beta):
    """Exponentially-weighted RFF-KRLS over an N-sample chunk (paper §6).

    Carries (theta [D], P [D,D]); P is initialised to I/lambda by the
    caller (regularisation parameter lambda enters only there).

    Args:
      beta: [1] forgetting factor (e.g. 0.9995).

    Returns (theta_out [D], p_out [D,D], errors [N]).
    """
    z = rff_features(x, omega, b)
    beta_s = beta[0]

    def step(carry, inp):
        th, pm = carry
        zn, yn = inp
        pi = pm @ zn  # [D]
        denom = beta_s + jnp.dot(zn, pi)
        k = pi / denom
        e = yn - jnp.dot(th, zn)
        th = th + k * e
        pm = (pm - jnp.outer(k, pi)) / beta_s
        return (th, pm), e

    (theta_out, p_out), errors = lax.scan(step, (theta, p), (z, y))
    return theta_out, p_out, errors


def rff_features_batch(x, omega, b):
    """Bare feature-map artifact for the coordinator's dynamic batcher."""
    return rff_features(x, omega, b)


def rff_predict_batch(theta, x, omega, b):
    """Batched prediction y_hat = Z theta — the serving (inference) path."""
    z = rff_features(x, omega, b)
    return z @ theta


def gauss_kernel_batch(x, c, *, sigma):
    """Gaussian Gram block for the QKLMS cross-check path (sigma static)."""
    return gauss_kernel(x, c, sigma=sigma)
